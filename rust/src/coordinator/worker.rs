//! A shard worker = one core owning a contiguous slice of processors.
//!
//! Owns its nodes' load lists exclusively; all interaction is via
//! channels.  Intra-shard edges are solved locally through the same
//! [`balance_pool`] primitive the engines use; for a cross-shard edge the
//! owner of `u` is the edge master — the slave ships `v`'s mobile loads
//! (`Offer`), the master solves the two-bin problem and ships `v`'s share
//! back (`Settle`).  Every edge draws its randomness from
//! `Pcg64::for_edge(seed, round, edge)`, so a sharded run is bit-identical
//! to `bcm::Sequential` for any shard count.

use super::messages::{Ctl, Report, ShardMsg};
use super::shard::ShardPlan;
use crate::balancer::{balance_pool, PairAlgorithm, SortAlgo};
use crate::load::Load;
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// Bounded mid-round wait for peer messages: a dead peer surfaces as a
/// reported error instead of wedging the worker (and with it every later
/// `Cluster::shutdown`) forever.  Shorter than the leader's round
/// timeout so the error report arrives before the leader gives up.
const PEER_TIMEOUT: Duration = Duration::from_secs(30);

/// Algorithm a worker runs on its matched edges.
#[derive(Clone, Copy, Debug)]
pub enum WorkerAlgo {
    Greedy,
    SortedGreedy,
}

impl WorkerAlgo {
    pub fn pair(self) -> PairAlgorithm {
        match self {
            WorkerAlgo::Greedy => PairAlgorithm::Greedy,
            WorkerAlgo::SortedGreedy => PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        }
    }
}

/// One coordinator worker owning the contiguous node range
/// `lo..lo + nodes.len()`.
pub struct ShardWorker {
    pub shard: usize,
    /// First node id owned; `nodes[i]` holds node `lo + i`.
    pub lo: usize,
    pub nodes: Vec<Vec<Load>>,
    pub algo: PairAlgorithm,
    pub ctl_rx: Receiver<Ctl>,
    pub peer_rx: Receiver<ShardMsg>,
    pub peer_tx: Vec<Sender<ShardMsg>>,
    pub report_tx: Sender<Report>,
}

impl ShardWorker {
    /// Event loop; returns when `Ctl::Shutdown` arrives, the leader goes
    /// away, or a protocol violation is reported.
    pub fn run(mut self) {
        while let Ok(msg) = self.ctl_rx.recv() {
            match msg {
                Ctl::Round { round, seed, plan } => {
                    match self.run_round(round, seed, &plan.per_shard[self.shard]) {
                        Ok((movements, peer_msgs)) => {
                            let (min_weight, max_weight) = self.extremes();
                            let sent = self.report_tx.send(Report::Round {
                                shard: self.shard,
                                movements,
                                min_weight,
                                max_weight,
                                peer_msgs,
                            });
                            if sent.is_err() {
                                return;
                            }
                        }
                        Err(message) => {
                            let _ = self.report_tx.send(Report::Error {
                                shard: self.shard,
                                message,
                            });
                            return;
                        }
                    }
                }
                Ctl::PollWeights => {
                    let weights = self
                        .nodes
                        .iter()
                        .map(|node| node.iter().map(|l| l.weight).sum())
                        .collect();
                    let sent = self.report_tx.send(Report::Weights {
                        shard: self.shard,
                        weights,
                    });
                    if sent.is_err() {
                        return;
                    }
                }
                Ctl::Shutdown => {
                    let _ = self.report_tx.send(Report::Final {
                        shard: self.shard,
                        nodes: std::mem::take(&mut self.nodes),
                    });
                    return;
                }
            }
        }
    }

    /// Execute this shard's slice of one matching; returns the movement
    /// count of the edges this shard mastered and the number of peer
    /// messages sent.
    fn run_round(
        &mut self,
        round: usize,
        seed: u64,
        plan: &ShardPlan,
    ) -> Result<(usize, usize), String> {
        let mut peer_msgs = 0usize;
        // Phase 1 — offer first.  Channel sends never block, so no
        // ordering between shards can deadlock.
        for &(edge, v, master) in &plan.slave {
            let (mobile, pinned) = drain_mobile(&mut self.nodes[v as usize - self.lo]);
            peer_msgs += 1;
            if self.peer_tx[master]
                .send(ShardMsg::Offer {
                    edge,
                    loads: mobile,
                    pinned,
                })
                .is_err()
            {
                return Err(format!("peer shard {master} unreachable (offer, edge {edge})"));
            }
        }
        // Phase 2 — intra-shard edges, no messaging.
        let mut movements = 0usize;
        for &(edge, u, v) in &plan.local {
            let mut rng = Pcg64::for_edge(seed, round, edge);
            movements += self.balance_local(&mut rng, u, v);
        }
        // Phase 3 — serve master edges as offers arrive and absorb the
        // settles for slave edges.  Arrival order is irrelevant: each
        // edge's randomness is keyed on (seed, round, edge).
        let masters: BTreeMap<usize, (u32, usize)> = plan
            .master
            .iter()
            .map(|&(e, u, _v, slave)| (e, (u, slave)))
            .collect();
        let slaves: BTreeMap<usize, u32> =
            plan.slave.iter().map(|&(e, v, _)| (e, v)).collect();
        let mut pending_masters = masters.len();
        let mut pending_slaves = slaves.len();
        while pending_masters > 0 || pending_slaves > 0 {
            let msg = match self.peer_rx.recv_timeout(PEER_TIMEOUT) {
                Ok(m) => m,
                Err(RecvTimeoutError::Timeout) => {
                    return Err(format!(
                        "timed out waiting for peer messages \
                         ({pending_masters} offers, {pending_slaves} settles outstanding)"
                    ))
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err("peer channels closed mid-round".to_string())
                }
            };
            match msg {
                ShardMsg::Offer {
                    edge,
                    loads,
                    pinned,
                } => {
                    let &(u, slave) = masters
                        .get(&edge)
                        .ok_or_else(|| format!("offer for unmastered edge {edge}"))?;
                    let mut rng = Pcg64::for_edge(seed, round, edge);
                    movements += self.balance_master(&mut rng, edge, u, (loads, pinned), slave)?;
                    peer_msgs += 1; // the settle just sent
                    pending_masters -= 1;
                }
                ShardMsg::Settle { edge, loads } => {
                    let &v = slaves
                        .get(&edge)
                        .ok_or_else(|| format!("settle for unslaved edge {edge}"))?;
                    // pinned loads stayed put in phase 1; the settled
                    // mobile loads are appended, exactly like the engines.
                    self.nodes[v as usize - self.lo].extend(loads);
                    pending_slaves -= 1;
                }
            }
        }
        Ok((movements, peer_msgs))
    }

    /// Rebalance an intra-shard edge in place.  Pool order (u then v),
    /// pinned handling and RNG consumption mirror `balance_pair` exactly.
    fn balance_local(&mut self, rng: &mut Pcg64, u: u32, v: u32) -> usize {
        let (ui, vi) = (u as usize - self.lo, v as usize - self.lo);
        let (u_node, v_node) = two_mut(&mut self.nodes, ui, vi);
        let (u_mobile, u_pinned) = drain_mobile(u_node);
        let (v_mobile, v_pinned) = drain_mobile(v_node);
        let pool: Vec<(Load, u8)> = u_mobile
            .into_iter()
            .map(|l| (l, 0))
            .chain(v_mobile.into_iter().map(|l| (l, 1)))
            .collect();
        let out = balance_pool(pool, [u_pinned, v_pinned], self.algo, rng);
        u_node.extend(out.to_u);
        v_node.extend(out.to_v);
        out.movements
    }

    /// Rebalance a cross-shard edge from the slave's offer; returns the
    /// movement count after sending the settle.
    fn balance_master(
        &mut self,
        rng: &mut Pcg64,
        edge: usize,
        u: u32,
        offer: (Vec<Load>, f64),
        slave: usize,
    ) -> Result<usize, String> {
        let (their_loads, their_pinned) = offer;
        let u_node = &mut self.nodes[u as usize - self.lo];
        let (u_mobile, u_pinned) = drain_mobile(u_node);
        let pool: Vec<(Load, u8)> = u_mobile
            .into_iter()
            .map(|l| (l, 0))
            .chain(their_loads.into_iter().map(|l| (l, 1)))
            .collect();
        let out = balance_pool(pool, [u_pinned, their_pinned], self.algo, rng);
        u_node.extend(out.to_u);
        self.peer_tx[slave]
            .send(ShardMsg::Settle {
                edge,
                loads: out.to_v,
            })
            .map_err(|_| format!("peer shard {slave} unreachable (settle, edge {edge})"))?;
        Ok(out.movements)
    }

    /// `(min, max)` node weight over the shard's nodes; the leader folds
    /// the shards' extremes into the global discrepancy (f64 min/max are
    /// exactly associative, so the fold order cannot change the result).
    fn extremes(&self) -> (f64, f64) {
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for node in &self.nodes {
            let w: f64 = node.iter().map(|l| l.weight).sum();
            min = min.min(w);
            max = max.max(w);
        }
        (min, max)
    }
}

/// Remove and return a node's mobile loads (in order) plus its pinned
/// weight sum, leaving the pinned loads in place — the same partition
/// (and the same f64 summation order) `balance_pair` performs on the
/// full load list.
fn drain_mobile(node: &mut Vec<Load>) -> (Vec<Load>, f64) {
    let mut mobile = Vec::with_capacity(node.len());
    let mut pinned_w = 0.0f64;
    let mut kept = Vec::new();
    for l in node.drain(..) {
        if l.mobile {
            mobile.push(l);
        } else {
            pinned_w += l.weight;
            kept.push(l);
        }
    }
    *node = kept;
    (mobile, pinned_w)
}

/// Disjoint `&mut` views of two distinct entries of `nodes`.
fn two_mut(nodes: &mut [Vec<Load>], a: usize, b: usize) -> (&mut Vec<Load>, &mut Vec<Load>) {
    debug_assert_ne!(a, b, "matching contains a self-loop");
    if a < b {
        let (lo, hi) = nodes.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = nodes.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drain_mobile_partitions_in_order() {
        let mut node = vec![
            Load::new(0, 1.0),
            Load::pinned(1, 2.0),
            Load::new(2, 3.0),
            Load::pinned(3, 4.0),
        ];
        let (mobile, pinned_w) = drain_mobile(&mut node);
        assert_eq!(mobile.iter().map(|l| l.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(node.iter().map(|l| l.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(pinned_w, 6.0);
    }

    #[test]
    fn two_mut_returns_disjoint_views_either_order() {
        let mut nodes = vec![vec![Load::new(0, 1.0)], vec![], vec![Load::new(1, 2.0)]];
        {
            let (a, b) = two_mut(&mut nodes, 2, 0);
            assert_eq!(a[0].id, 1);
            assert_eq!(b[0].id, 0);
            let l = b.pop().unwrap();
            a.push(l);
        }
        assert!(nodes[0].is_empty());
        assert_eq!(nodes[2].len(), 2);
    }

    #[test]
    fn worker_algo_maps_to_pair_algorithms() {
        assert_eq!(WorkerAlgo::Greedy.pair(), PairAlgorithm::Greedy);
        assert_eq!(
            WorkerAlgo::SortedGreedy.pair(),
            PairAlgorithm::SortedGreedy(SortAlgo::Quick)
        );
    }
}
