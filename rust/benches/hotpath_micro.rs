//! Hot-path microbenchmarks (the §Perf deliverable's measurement tool).
//!
//! Measures, on the end-to-end BCM round hot path:
//!   1. pure-Rust pairwise rebalance throughput (edges/s, balls/s)
//!   2. device-path (PJRT) batched round latency per bucket
//!   3. the sequential engine's full-round throughput
//!   4. the distributed cluster's round latency
//!
//! Results feed EXPERIMENTS.md §Perf.

use bcm_dlb::balancer::{balance_pair, PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{balance_round, Schedule};
use bcm_dlb::coordinator::{Cluster, WorkerAlgo};
use bcm_dlb::graph::Topology;
use bcm_dlb::load::{Load, LoadState, Mobility, WeightDistribution};
use bcm_dlb::runtime::{solve_batch, DeviceAlgo, EdgeProblem, Runtime};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::table::{f, Table};
use std::time::Instant;

fn bench<T>(iters: usize, mut body: impl FnMut() -> T) -> f64 {
    // one warmup
    std::hint::black_box(body());
    let start = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(body());
    }
    start.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let mut t = Table::new(
        "hot-path microbenchmarks",
        &["benchmark", "time/op", "throughput"],
    );

    // 1. pairwise rebalance (the innermost hot path)
    for (label, algo) in [
        ("balance_pair greedy, 2x50 balls", PairAlgorithm::Greedy),
        (
            "balance_pair sorted:quick, 2x50 balls",
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        ),
        (
            "balance_pair sorted:std, 2x50 balls",
            PairAlgorithm::SortedGreedy(SortAlgo::Std),
        ),
    ] {
        let mut rng = Pcg64::new(1);
        let u: Vec<Load> = (0..50).map(|i| Load::new(i, rng.uniform(0.0, 100.0))).collect();
        let v: Vec<Load> = (0..50)
            .map(|i| Load::new(100 + i, rng.uniform(0.0, 100.0)))
            .collect();
        let s = bench(2000, || balance_pair(&u, &v, algo, &mut rng));
        t.row(vec![
            label.into(),
            format!("{:.2} us", s * 1e6),
            format!("{:.2} Mballs/s", 100.0 / s / 1e6),
        ]);
    }

    // 2. one full sequential-engine round on the paper's largest setting
    {
        let mut rng = Pcg64::new(2);
        let g = Topology::RandomConnected.build(128, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state = LoadState::init_uniform_counts(
            128,
            100,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let pairs = schedule.matching(0).to_vec();
        // reset the state every iteration so the measured work is stable
        // (a balanced state has different pool sizes than the initial one)
        let s = bench(200, || {
            let mut st = state.clone();
            balance_round(&mut st, &pairs, DeviceAlgo::SortedGreedy, None, &mut rng).unwrap()
        });
        t.row(vec![
            format!("engine round n=128 L/n=100 ({} edges), rust path", pairs.len()),
            format!("{:.1} us", s * 1e6),
            format!("{:.2} Medges/s", pairs.len() as f64 / s / 1e6),
        ]);
    }

    // 3. PJRT device path (if artifacts are built)
    let dir = bcm_dlb::runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let mut rt = Runtime::new(&dir).expect("runtime");
        rt.warm_entry("balance_two_bin").expect("warm");
        for (b, m) in [(64usize, 100usize), (64, 200), (8, 500)] {
            let mut rng = Pcg64::new(3);
            let problems: Vec<EdgeProblem> = (0..b)
                .map(|_| EdgeProblem {
                    weights: (0..m).map(|_| rng.uniform(0.0, 100.0)).collect(),
                    hosts: (0..m).map(|_| rng.below(2) as u8).collect(),
                    base: [0.0, 0.0],
                })
                .collect();
            let s_dev = bench(20, || {
                solve_batch(Some(&mut rt), DeviceAlgo::SortedGreedy, &problems).unwrap()
            });
            let s_fb = bench(50, || {
                solve_batch(None, DeviceAlgo::SortedGreedy, &problems).unwrap()
            });
            t.row(vec![
                format!("device batch {b} edges x {m} balls (PJRT)"),
                format!("{:.2} ms", s_dev * 1e3),
                format!("{:.0} kball/s", b as f64 * m as f64 / s_dev / 1e3),
            ]);
            t.row(vec![
                format!("same batch, rust fallback"),
                format!("{:.3} ms", s_fb * 1e3),
                format!(
                    "{:.0} kball/s (device/fallback = {:.0}x)",
                    b as f64 * m as f64 / s_fb / 1e3,
                    s_dev / s_fb
                ),
            ]);
        }
    } else {
        eprintln!("artifacts/ absent — skipping PJRT microbenches");
    }

    // 4. distributed cluster round latency (n=64)
    {
        let mut rng = Pcg64::new(4);
        let g = Topology::RandomConnected.build(64, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state = LoadState::init_uniform_counts(
            64,
            100,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let mut cluster = Cluster::spawn(state, WorkerAlgo::SortedGreedy);
        let mut round = 0usize;
        let s = bench(50, || {
            let st = cluster
                .run_single_round(&schedule, round, &mut rng)
                .expect("cluster round failed");
            round += 1;
            st
        });
        cluster.shutdown().expect("cluster shutdown failed");
        t.row(vec![
            "cluster round n=64 L/n=100 (sharded, one worker/core)".into(),
            format!("{:.2} ms", s * 1e3),
            format!("{:.0} rounds/s", 1.0 / s),
        ]);
    }

    println!("{}", t.render());
    t.write_csv(std::path::Path::new("results/hotpath_micro.csv")).ok();
    let _ = f(0.0, 0); // keep table::f linked for formatting parity
}
