//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The offline image cannot vendor the real `xla` dependency tree, but
//! `runtime::client`'s PJRT-backed implementation should still
//! *compile* so the `pjrt` cargo feature can be type-checked in CI
//! (`cargo check --features pjrt`) and the real crate can be dropped in
//! without code changes.  This stub therefore mirrors exactly the API
//! surface `runtime::client` uses — same type names, same signatures —
//! with every runtime entry point returning an [`Error`]: construction
//! of a [`PjRtClient`] fails, so no artifact can ever appear to
//! "execute" against fake results.
//!
//! Swap in the real bindings by pointing the `xla` path dependency in
//! `Cargo.toml` at a genuine checkout instead of `vendor/xla`.

use std::fmt;

/// Stub error: carries the name of the entry point that was called.
#[derive(Debug)]
pub struct Error {
    what: &'static str,
}

impl Error {
    fn stub(what: &'static str) -> Error {
        Error { what }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: this build links the vendored xla API stub (no real PJRT); \
             point the `xla` path dependency at a real checkout",
            self.what
        )
    }
}

impl std::error::Error for Error {}

/// Stub result alias matching the real crate's.
pub type Result<T> = std::result::Result<T, Error>;

/// A host-side literal (tensor) value.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 f32 literal (stub: carries no data).
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("Literal::reshape"))
    }

    /// Split a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }
}

/// A device-resident buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Transfer the buffer back to a host [`Literal`].
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; returns per-device, per-output
    /// buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client.  The stub always errors, so callers fall
    /// back cleanly instead of computing against fake devices.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    /// Name of the PJRT platform backing this client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation for this client's devices.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

/// A parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO module from its text representation on disk.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_runtime_entry_point_errors() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal::vec1(&[]).to_tuple().is_err());
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("stub"), "unexpected error text: {err}");
    }
}
