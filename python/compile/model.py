"""Layer 2: the JAX compute graph the Rust coordinator executes.

Each entry point composes Layer-1 Pallas kernels into one jit-able function
that python/compile/aot.py lowers ONCE to HLO text per shape bucket.  Rust
loads the artifacts via PJRT at startup; Python never runs on the request
path.

Entry points
------------
balance_two_bin(weights, base)
    The BCM hot path: all concurrent matchings of one round, batched.
    SortedGreedy = bitonic_sort_desc -> two_bin_greedy, fused into a single
    HLO module so the sorted weights never leave the device.
    Returns (sorted_w, perm, assign, sums).

offline_nbin(weights, base)
    Appendix-C offline solver: sort + n-bin greedy placement.
    Returns (sorted_w, perm, assign, sums).

continuous_round(x, m)
    Continuous-case oracle step x <- x @ M (round matrix application).

greedy_two_bin(weights, base)
    The *unsorted* Greedy baseline on the same batched layout (no sort
    stage) — used by benches to run the paper's baseline through the
    identical device path.
"""

from __future__ import annotations

from .kernels.bitonic import bitonic_sort_desc
from .kernels.diffusion import diffusion_step
from .kernels.nbin import nbin_greedy
from .kernels.two_bin import two_bin_greedy


def balance_two_bin(weights, base):
    """SortedGreedy over a batch of two-bin matchings: sort, then place."""
    sorted_w, perm = bitonic_sort_desc(weights)
    assign, sums = two_bin_greedy(sorted_w, base)
    return sorted_w, perm, assign, sums


def greedy_two_bin(weights, base):
    """Greedy baseline: place in arrival order, no sorting stage."""
    assign, sums = two_bin_greedy(weights, base)
    return assign, sums


def offline_nbin(weights, base):
    """Offline weighted balls-into-bins with N bins (SortedGreedy)."""
    sorted_w, perm = bitonic_sort_desc(weights)
    assign, sums = nbin_greedy(sorted_w, base)
    return sorted_w, perm, assign, sums


def continuous_round(x, m):
    """One continuous-case BCM round for a batch of load vectors."""
    return (diffusion_step(x, m),)
