"""diffusion_step Pallas kernel vs numpy matmul oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly offline
from hypothesis import given, settings, strategies as st

from compile.kernels.diffusion import diffusion_step
from compile.kernels import ref


def matching_round_matrix(n, pairs):
    """Build a BCM matching matrix M^(t) from disjoint (u, v) pairs."""
    m = np.eye(n, dtype=np.float32)
    for u, v in pairs:
        m[u, u] = m[v, v] = m[u, v] = m[v, u] = 0.5
    return m


def test_identity_matrix_is_noop():
    x = np.arange(32, dtype=np.float32).reshape(2, 16)
    out = diffusion_step(jnp.asarray(x), jnp.eye(16, dtype=np.float32))
    np.testing.assert_allclose(np.asarray(out), x)


def test_single_matching_averages_pair():
    n = 8
    m = matching_round_matrix(n, [(0, 1)])
    x = np.zeros((1, n), np.float32)
    x[0, 0] = 10.0
    out = np.asarray(diffusion_step(jnp.asarray(x), jnp.asarray(m)))
    assert out[0, 0] == pytest.approx(5.0)
    assert out[0, 1] == pytest.approx(5.0)
    assert out[0, 2:].sum() == 0.0


def test_mass_conserved_by_doubly_stochastic():
    rng = np.random.default_rng(1)
    n = 16
    m = matching_round_matrix(n, [(0, 3), (1, 2), (4, 5)])
    x = rng.uniform(0, 100, (4, n)).astype(np.float32)
    out = np.asarray(diffusion_step(jnp.asarray(x), jnp.asarray(m)))
    np.testing.assert_allclose(out.sum(axis=1), x.sum(axis=1), rtol=1e-5)


def test_blocked_grid_matches_whole():
    rng = np.random.default_rng(2)
    x = rng.uniform(0, 1, (8, 32)).astype(np.float32)
    m = rng.uniform(0, 1, (32, 32)).astype(np.float32)
    whole = np.asarray(diffusion_step(jnp.asarray(x), jnp.asarray(m)))
    tiled = np.asarray(
        diffusion_step(jnp.asarray(x), jnp.asarray(m), block_b=2, block_n=8)
    )
    np.testing.assert_allclose(whole, tiled, rtol=1e-5)


def test_rejects_bad_shapes():
    with pytest.raises(ValueError):
        diffusion_step(jnp.zeros((2, 8)), jnp.zeros((4, 4)))


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 8]),
    n=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_matches_numpy(b, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 10, (b, n)).astype(np.float32)
    m = rng.uniform(0, 1, (n, n)).astype(np.float32)
    out = np.asarray(diffusion_step(jnp.asarray(x), jnp.asarray(m)))
    np.testing.assert_allclose(out, ref.ref_diffusion(x, m), rtol=1e-4)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_repeated_rounds_converge(seed):
    """Ergodic round matrix: repeated application converges to the mean
    (continuous-case convergence, paper §3)."""
    rng = np.random.default_rng(seed)
    n = 8
    m1 = matching_round_matrix(n, [(i, i + 1) for i in range(0, n, 2)])
    m2 = matching_round_matrix(n, [(i, i + 1) for i in range(1, n - 1, 2)] + [(0, n - 1)])
    m = (m1 @ m2).astype(np.float32)
    x = rng.uniform(0, 100, (1, n)).astype(np.float32)
    y = jnp.asarray(x)
    for _ in range(200):
        y = diffusion_step(y, jnp.asarray(m))
    y = np.asarray(y)
    np.testing.assert_allclose(y, x.mean(), rtol=1e-3)
