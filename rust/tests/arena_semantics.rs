//! Seed-sweep properties of the SoA arena `LoadState`: the arena (and
//! the scratch-based edge path on top of it) must round-trip the
//! historical per-node-`Vec` semantics exactly — same load orders, same
//! pinning, and cached weight totals bitwise equal to a fresh in-order
//! fold at all times.
//!
//! Same harness idiom as `property_invariants.rs` (which is left
//! untouched as the frozen pre-arena contract): each property runs over
//! many deterministic seeds and reports the failing seed.

use bcm_dlb::balancer::{EdgeScratch, PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{
    balance_edge_with, parallel_round, Engine, Parallel, Schedule, Sequential, StopRule,
};
use bcm_dlb::graph::Graph;
use bcm_dlb::load::{Load, LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::workload::{apply_ops, apply_ops_nodes, ops_for_round, TrafficConfig};

/// Run `prop` over `cases` seeds; panic with the seed on failure.
fn forall(name: &str, cases: u64, prop: impl Fn(&mut Pcg64)) {
    for seed in 0..cases {
        let mut rng = Pcg64::new(0xA2E4_0000 + seed);
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn random_dist(rng: &mut Pcg64) -> WeightDistribution {
    match rng.below(4) {
        0 => WeightDistribution::Uniform { lo: 0.0, hi: 100.0 },
        1 => WeightDistribution::Exponential { mean: 10.0 },
        2 => WeightDistribution::Normal { mean: 20.0, std: 8.0 },
        _ => WeightDistribution::Pareto { scale: 1.0, alpha: 2.5 },
    }
}

fn random_algo(rng: &mut Pcg64) -> PairAlgorithm {
    match rng.below(4) {
        0 => PairAlgorithm::Greedy,
        1 => PairAlgorithm::GreedyIncremental,
        2 => PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        _ => PairAlgorithm::Random,
    }
}

/// The cached per-node totals stay bitwise equal to a fresh left fold
/// of the node's weights — after thousands of migrations, relocations
/// and compactions, not just after construction.
#[test]
fn prop_cached_totals_bitwise_equal_fresh_fold_after_migrations() {
    forall("totals 0 ULP", 15, |rng| {
        let n = 12 + rng.below(20);
        let g = Graph::random_connected(n, rng);
        let schedule = Schedule::from_graph(&g);
        let mobility = if rng.coin() { Mobility::Full } else { Mobility::Partial };
        let mut state = LoadState::init_uniform_counts(
            n,
            2 + rng.below(12),
            &random_dist(rng),
            mobility,
            rng,
        );
        let algo = random_algo(rng);
        let seed = rng.next_u64();
        Sequential.run(&mut state, &schedule, algo, StopRule::sweeps(40), seed);
        for v in 0..state.n() {
            let fresh = state
                .node(v)
                .iter()
                .map(|l| l.weight)
                .fold(0.0f64, |acc, w| acc + w);
            assert_eq!(
                state.node_weight(v).to_bits(),
                fresh.to_bits(),
                "cached total of node {v} drifted from the in-order fold"
            );
        }
    });
}

/// Partial-mobility pinning round-trips the old semantics: pinned loads
/// never change node, weight, or relative order, no matter how much the
/// mobile loads around them migrate (sequentially or in parallel).
#[test]
fn prop_pinned_loads_never_move() {
    forall("pinning", 15, |rng| {
        let n = 8 + rng.below(16);
        let g = Graph::random_connected(n, rng);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::init_uniform_counts(
            n,
            2 + rng.below(10),
            &random_dist(rng),
            Mobility::Partial,
            rng,
        );
        let pinned_before: Vec<(usize, u64, u64)> = (0..n)
            .flat_map(|v| {
                state
                    .node(v)
                    .iter()
                    .filter(|l| !l.mobile)
                    .map(move |l| (v, l.id, l.weight.to_bits()))
                    .collect::<Vec<_>>()
            })
            .collect();
        assert!(!pinned_before.is_empty(), "Partial mobility must pin something");
        let ids_before = state.all_ids();
        let algo = random_algo(rng);
        let threads = 1 + rng.below(4);
        let seed = rng.next_u64();
        Parallel::new(threads).run(&mut state, &schedule, algo, StopRule::sweeps(8), seed);
        let pinned_after: Vec<(usize, u64, u64)> = (0..n)
            .flat_map(|v| {
                state
                    .node(v)
                    .iter()
                    .filter(|l| !l.mobile)
                    .map(move |l| (v, l.id, l.weight.to_bits()))
                    .collect::<Vec<_>>()
            })
            .collect();
        assert_eq!(pinned_before, pinned_after, "a pinned load moved or reordered");
        assert_eq!(state.all_ids(), ids_before, "loads were lost or duplicated");
    });
}

/// The raw `EdgeViews` path (split_pairs → gather/try_apply, including
/// the deferred-relocation fallback) produces states and movement
/// counts identical to the owner's gather_edge/apply_edge on random
/// matchings.
#[test]
fn prop_edge_views_match_owner_application() {
    forall("views == owner", 30, |rng| {
        let n = 6 + rng.below(20);
        let mut state = LoadState::init_uniform_counts(
            n,
            1 + rng.below(10),
            &random_dist(rng),
            if rng.coin() { Mobility::Full } else { Mobility::Partial },
            rng,
        );
        if rng.coin() {
            // skew one node so write-backs overflow caps and defer
            for i in 0..32u64 {
                state.push(0, Load::new(1_000_000 + i, 0.25));
            }
        }
        // a random matching: shuffle the vertices, pair them up
        let mut verts: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut verts);
        let pairs: Vec<(u32, u32)> = verts.chunks_exact(2).map(|c| (c[0], c[1])).collect();
        let algo = random_algo(rng);
        let seed = rng.next_u64();
        let round = rng.below(1000);
        let mut via_views = state.clone();
        let threads = 1 + rng.below(4);
        let mv = parallel_round(&mut via_views, &pairs, round, algo, seed, threads);
        let mut scratch = EdgeScratch::new();
        let mut mo = 0usize;
        for (e, &(u, v)) in pairs.iter().enumerate() {
            let mut edge_rng = Pcg64::for_edge(seed, round, e);
            mo += balance_edge_with(
                &mut state,
                u as usize,
                v as usize,
                algo,
                &mut edge_rng,
                &mut scratch,
            );
        }
        assert_eq!(mv, mo, "movement counts diverged");
        assert_eq!(via_views, state, "states diverged");
    });
}

/// Live churn interleaved with balancing sweeps: arrivals, departures
/// and cost drift exercise the arena's insert / relocate / compact
/// paths *between* migration rounds, and at every round boundary the
/// cached per-node totals must still be bitwise equal to a fresh
/// in-order fold, ids must stay unique, and pinned loads must stay put
/// (drift may rescale their weight — immobility forbids migration, not
/// cost change).
#[test]
fn prop_churned_sweeps_keep_totals_ids_and_pinning() {
    forall("churn + sweeps invariants", 10, |rng| {
        let n = 8 + rng.below(12);
        let g = Graph::random_connected(n, rng);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::init_uniform_counts(
            n,
            2 + rng.below(8),
            &random_dist(rng),
            Mobility::Partial,
            rng,
        );
        let pinned_ids: Vec<(usize, u64)> = (0..n)
            .flat_map(|v| {
                state
                    .node(v)
                    .iter()
                    .filter(|l| !l.mobile)
                    .map(move |l| (v, l.id))
                    .collect::<Vec<_>>()
            })
            .collect();
        assert!(!pinned_ids.is_empty(), "Partial mobility must pin something");
        let cfg = TrafficConfig {
            arrival_rate: 2.0,
            ..TrafficConfig::default()
        };
        let wseed = rng.next_u64();
        let algo = random_algo(rng);
        let seed = rng.next_u64();
        let mut scratch = EdgeScratch::new();
        for round in 0..4 * schedule.period() {
            apply_ops(&mut state, &ops_for_round(&cfg, wseed, round, n));
            for (e, &(u, v)) in schedule.matching(round).iter().enumerate() {
                let mut edge_rng = Pcg64::for_edge(seed, round, e);
                balance_edge_with(&mut state, u as usize, v as usize, algo, &mut edge_rng, &mut scratch);
            }
            // cached totals: 0 ULP against a fresh in-order fold
            for v in 0..n {
                let fresh = state
                    .node(v)
                    .iter()
                    .map(|l| l.weight)
                    .fold(0.0f64, |acc, w| acc + w);
                assert_eq!(
                    state.node_weight(v).to_bits(),
                    fresh.to_bits(),
                    "cached total of node {v} drifted at round {round}"
                );
            }
            // ids unique after arrivals + departures
            let ids = state.all_ids();
            for w in ids.windows(2) {
                assert!(w[0] != w[1], "duplicate id {} at round {round}", w[0]);
            }
        }
        // pinned loads never migrated or departed
        for &(v, id) in &pinned_ids {
            assert!(
                state.node(v).iter().any(|l| l.id == id && !l.mobile),
                "pinned load {id} left node {v} under churn"
            );
        }
        // PartialEq is layout-blind: a state rebuilt by fresh in-order
        // pushes (a compact, never-relocated arena) equals the churned
        // arena, whatever slot arrangement churn left behind
        let mut rebuilt = LoadState::empty(n);
        for v in 0..n {
            for l in state.node(v).iter() {
                rebuilt.push(v, *l);
            }
        }
        rebuilt.reserve_ids(state.next_id());
        assert_eq!(rebuilt, state, "PartialEq saw arena layout, not content");
    });
}

/// The arena mirrors the plain `Vec<Vec<Load>>` model when churn ops
/// are thrown into the mixed-op soup: [`apply_ops`] on the arena and
/// [`apply_ops_nodes`] on the model must stay in lock-step through
/// arbitrary interleavings with push / take_mobile+give / take_node.
#[test]
fn prop_arena_matches_vec_model_with_churn_in_the_mix() {
    forall("arena == Vec model + churn", 25, |rng| {
        let n = 1 + rng.below(6);
        let mut s = LoadState::empty(n);
        let mut model: Vec<Vec<Load>> = vec![Vec::new(); n];
        let cfg = TrafficConfig {
            arrival_rate: 2.0,
            ..TrafficConfig::default()
        };
        let wseed = rng.next_u64();
        let mut round = 0usize;
        let mut next = 0u64;
        for _ in 0..200 {
            let v = rng.below(n);
            match rng.below(4) {
                0 => {
                    let mut l = Load::new(next, rng.uniform(0.0, 10.0));
                    l.mobile = rng.next_f64() < 0.8;
                    next += 1;
                    s.push(v, l);
                    model[v].push(l);
                }
                1 => {
                    let got = s.take_mobile(v);
                    let want: Vec<Load> =
                        model[v].iter().copied().filter(|l| l.mobile).collect();
                    model[v].retain(|l| !l.mobile);
                    assert_eq!(got, want, "take_mobile order diverged");
                    let to = rng.below(n);
                    s.give(to, got.iter().copied());
                    model[to].extend(got);
                }
                2 => {
                    let ops = ops_for_round(&cfg, wseed, round, n);
                    round += 1;
                    apply_ops(&mut s, &ops);
                    apply_ops_nodes(&mut model, 0, &ops);
                }
                _ => {
                    assert_eq!(s.node(v).to_vec(), model[v]);
                    let fresh: f64 =
                        model[v].iter().map(|l| l.weight).fold(0.0f64, |acc, w| acc + w);
                    assert_eq!(
                        s.node_weight(v).to_bits(),
                        fresh.to_bits(),
                        "cached total drifted mid-sequence"
                    );
                }
            }
        }
        for v in 0..n {
            assert_eq!(s.node(v).to_vec(), model[v], "final content of node {v}");
            let fresh: f64 =
                model[v].iter().map(|l| l.weight).fold(0.0f64, |acc, w| acc + w);
            assert_eq!(s.node_weight(v).to_bits(), fresh.to_bits());
        }
        assert_eq!(s.total_loads(), model.iter().map(|m| m.len()).sum::<usize>());
    });
}

/// The arena mirrors a plain `Vec<Vec<Load>>` model through arbitrary
/// interleavings of push / take_mobile+give / take_node — same
/// sequences, same totals (to the bit), same disjoint slot ranges.
#[test]
fn prop_arena_matches_vec_model_under_mixed_ops() {
    forall("arena == Vec model", 40, |rng| {
        let n = 1 + rng.below(8);
        let mut s = LoadState::empty(n);
        let mut model: Vec<Vec<Load>> = vec![Vec::new(); n];
        let mut next = 0u64;
        for _ in 0..400 {
            let v = rng.below(n);
            match rng.below(4) {
                0 => {
                    let mut l = Load::new(next, rng.uniform(0.0, 10.0));
                    l.mobile = rng.next_f64() < 0.8;
                    next += 1;
                    s.push(v, l);
                    model[v].push(l);
                }
                1 => {
                    let got = s.take_mobile(v);
                    let want: Vec<Load> =
                        model[v].iter().copied().filter(|l| l.mobile).collect();
                    model[v].retain(|l| !l.mobile);
                    assert_eq!(got, want, "take_mobile order diverged");
                    let to = rng.below(n);
                    s.give(to, got.iter().copied());
                    model[to].extend(got);
                }
                2 => {
                    let got = s.take_node(v);
                    assert_eq!(got, model[v], "take_node order diverged");
                    model[v].clear();
                }
                _ => {
                    assert_eq!(s.node(v).to_vec(), model[v]);
                    let fresh: f64 =
                        model[v].iter().map(|l| l.weight).fold(0.0f64, |acc, w| acc + w);
                    assert_eq!(
                        s.node_weight(v).to_bits(),
                        fresh.to_bits(),
                        "cached total drifted mid-sequence"
                    );
                }
            }
        }
        for v in 0..n {
            assert_eq!(s.node(v).to_vec(), model[v], "final content of node {v}");
        }
        assert_eq!(s.total_loads(), model.iter().map(|m| m.len()).sum::<usize>());
    });
}
