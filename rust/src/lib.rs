//! # bcm-dlb
//!
//! Production reproduction of **"Balancing indivisible real-valued loads
//! in arbitrary networks"** (Demirel & Sbalzarini, 2013) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the distributed coordination system: the
//!   balancing circuit model (BCM) protocol, network substrate, local
//!   balancers (`Greedy`, `SortedGreedy`), metrics, theory bounds, and a
//!   sharded leader/worker runtime (`coordinator`: one worker per core
//!   owning a contiguous node shard, O(cut) messaging).  Rounds execute
//!   through the [`bcm::Engine`] trait: [`bcm::Sequential`] (reference)
//!   or [`bcm::Parallel`] (scoped threads over vertex-disjoint
//!   matchings); both engines and the cluster are bit-identical for any
//!   worker count via counter-based per-edge RNG streams.
//! * **Layer 2/1 (python/, build-time only)** — the batched per-round
//!   rebalance lowered AOT to HLO-text artifacts, executed at runtime via
//!   PJRT (`runtime` module).  Python never runs on the request path.
//!
//! See `DESIGN.md` for the system inventory and experiment index
//! (§8 specifies the cluster's checkpoint/rejoin/reassign recovery
//! contract), `OPERATIONS.md` for the operator handbook — deploy
//! modes, failure matrix, and recovery drills — and `EXPERIMENTS.md`
//! for paper-vs-measured results.

pub mod balancer;
pub mod coordinator;
pub mod bcm;
pub mod cli;
pub mod config;
pub mod graph;
pub mod load;
pub mod runtime;
pub mod service;
pub mod experiments;
pub mod theory;
pub mod util;
pub mod workload;
