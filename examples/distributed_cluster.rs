//! E10 — the distributed leader/worker coordinator serving BCM rounds.
//!
//! ```bash
//! cargo run --release --example distributed_cluster
//! ```
//!
//! Spawns one worker thread per processor (64 nodes); workers exchange
//! loads pairwise over channels exactly as the paper's matching model
//! prescribes (one-to-one communication per round), while the leader only
//! sequences rounds and aggregates metrics.  Reports throughput and
//! per-round latency percentiles, then verifies against the sequential
//! reference engine.

use bcm_dlb::bcm::Schedule;
use bcm_dlb::coordinator::{Cluster, WorkerAlgo};
use bcm_dlb::graph::Topology;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::stats::percentile;
use std::time::Instant;

fn main() {
    let n = 64;
    let loads_per_node = 100;
    let sweeps = 10;
    let mut rng = Pcg64::new(1);

    let g = Topology::RandomConnected.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        n,
        loads_per_node,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let total_loads = state.total_loads();
    let init_disc = state.discrepancy();
    println!(
        "cluster: {n} workers, {total_loads} loads, d={} colors, initial discrepancy {init_disc:.1}",
        schedule.period()
    );

    let mut cluster = Cluster::spawn(state, WorkerAlgo::SortedGreedy);

    // Per-round latency measurement: drive rounds one by one.
    let mut latencies_ms = Vec::new();
    let mut total_edges = 0usize;
    let start = Instant::now();
    let trace = {
        let mut trace_rounds = Vec::new();
        let d = schedule.period();
        for round in 0..sweeps * d {
            let t0 = Instant::now();
            let pairs = schedule.matching(round).to_vec();
            total_edges += pairs.len();
            // run one round through the public API
            let t = cluster.run_single_round(&schedule, round, &mut rng);
            latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            trace_rounds.push(t);
        }
        trace_rounds
    };
    let wall = start.elapsed().as_secs_f64();
    let final_disc = cluster.poll_discrepancy();
    let state = cluster.shutdown();

    let movements: usize = trace.iter().map(|r| r.movements).sum();
    println!("\nafter {} rounds ({wall:.2}s):", trace.len());
    println!(
        "  final discrepancy  {final_disc:.3}  ({}x reduction)",
        (init_disc / final_disc.max(1e-9)) as u64
    );
    println!("  edges balanced     {total_edges}  ({:.0} edges/s)", total_edges as f64 / wall);
    println!("  loads moved        {movements}");
    println!(
        "  round latency      p50 {:.2} ms   p99 {:.2} ms   max {:.2} ms",
        percentile(&latencies_ms, 50.0),
        percentile(&latencies_ms, 99.0),
        percentile(&latencies_ms, 100.0)
    );

    // consistency: the collected state matches the polled discrepancy
    assert_eq!(state.total_loads(), total_loads, "loads lost!");
    assert!((state.discrepancy() - final_disc).abs() < 1e-9);
    println!("\nconsistency checks passed (loads conserved, metrics agree)");
}
