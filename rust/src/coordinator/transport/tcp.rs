//! The TCP socket transport: the cluster protocol over real
//! `std::net::TcpStream`s, with the leader and every shard worker in
//! separate OS processes.
//!
//! # Topology
//!
//! One duplex leader<->worker connection per shard (control frames down,
//! report frames up) plus a full worker<->worker mesh for the
//! Offer/Settle data plane — the same channel graph as
//! [`local`](super::local), realized as sockets.
//!
//! # Connection establishment
//!
//! 1. Each worker process reaches the leader either by dialing it
//!    (`bcm-dlb cluster-worker --connect`, leader bound via
//!    [`LeaderListener`]) or by listening for the leader's dial-in
//!    (`--listen`, leader using [`TcpLeader::connect`], config key
//!    `peers`).  Either way the worker immediately binds an ephemeral
//!    **peer listener** and sends `Hello { peer_addr }`.
//! 2. Once all `k` workers are known, the leader assigns shard indices
//!    (connection order) and sends each worker an `Init` frame: its
//!    shard id, node range, initial load lists, the algorithm name, and
//!    the full peer-address table.
//! 3. Workers build the mesh: shard `s` dials every shard `< s`
//!    (announcing itself with `PeerHello`) and accepts a connection from
//!    every shard `> s`, so each unordered pair shares exactly one
//!    socket.
//!
//! # Blocking and ordering
//!
//! After the (blocking) handshake, every socket of an endpoint runs
//! nonblocking under one [`Poller`](super::poll::Poller): the leader
//! polls all `k` worker connections from its own thread, and each worker
//! polls its leader connection plus its whole peer mesh.  A blocked
//! receive (`recv_report`, `recv_ctl`, `recv_peer`) therefore keeps
//! draining **every** connection — frames destined for the other queue
//! are buffered, which preserves the pipelining the old per-socket
//! reader threads provided — and every poll pass retries buffered
//! writes, so sends never block indefinitely either.  No helper threads
//! exist anymore: shutting an endpoint down leaks nothing (asserted by
//! `tests/service_teardown.rs`).  Determinism is untouched because the
//! codec round-trips every `f64` bit-exactly and no RNG state ever
//! crosses a message — a loopback-TCP cluster run is **bit-identical**
//! to `bcm::Sequential` (asserted by `tests/tcp_cluster.rs`, which
//! spawns real worker processes).
//!
//! # Failure mapping
//!
//! A lost leader connection surfaces on the worker as a transport error
//! (the worker exits); a lost worker connection surfaces on the leader
//! as a synthesized `Report::Error` naming the shard, feeding the
//! existing fail-stop path; a lost peer connection surfaces on the
//! blocked worker as a `Closed` error that its round loop converts into
//! an `Error { round: Some(r), .. }` report — so disconnects name the
//! round they killed, exactly like the in-process backend.  The full
//! failure-mode table lives in DESIGN.md §6.

use super::codec::{read_frame, write_frame, Init, WireMsg};
use super::poll::{Event, Poller};
use super::{LeaderTransport, TransportError, WorkerTransport};
use crate::anyhow;
use crate::balancer::PairAlgorithm;
use crate::coordinator::messages::{Ctl, Report, ShardMsg};
use crate::coordinator::shard::{RoundPlan, ShardPlan};
use crate::coordinator::worker::ShardWorker;
use crate::load::Load;
use crate::util::error::{Context, Result};
use crate::util::rng::Pcg64;
use std::collections::VecDeque;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How long handshake reads (Hello/Init/PeerHello) and mesh accepts may
/// take before connection setup is declared failed.
pub(crate) const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(60);

/// Delay between worker connect retries (`--retry` attempts).
const CONNECT_RETRY_DELAY: Duration = Duration::from_millis(250);

/// Default number of connect attempts for workers and mesh dials.
pub const DEFAULT_CONNECT_RETRIES: usize = 40;

/// Dial `addr`, retrying on transient refusal so workers can start
/// before the other side has bound its socket.  Permanent errors (bad
/// address, permission) fail fast instead of burning the retry budget.
pub(crate) fn connect_with_retry(addr: &str, retries: usize) -> io::Result<TcpStream> {
    let attempts = retries.max(1);
    let mut last: Option<io::Error> = None;
    for i in 0..attempts {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => {
                let transient = matches!(
                    e.kind(),
                    io::ErrorKind::ConnectionRefused
                        | io::ErrorKind::ConnectionReset
                        | io::ErrorKind::ConnectionAborted
                        | io::ErrorKind::TimedOut
                );
                if !transient {
                    return Err(e);
                }
                last = Some(e);
            }
        }
        if i + 1 < attempts {
            std::thread::sleep(CONNECT_RETRY_DELAY);
        }
    }
    Err(last.unwrap_or_else(|| io::Error::other("no connect attempts made")))
}

/// Accept one connection with a deadline (std's blocking `accept` has
/// no timeout, so poll in non-blocking mode).
pub(crate) fn accept_with_deadline(
    listener: &TcpListener,
    deadline: Duration,
    what: &str,
) -> Result<TcpStream> {
    listener.set_nonblocking(true)?;
    let start = Instant::now();
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                listener.set_nonblocking(false)?;
                stream.set_nonblocking(false)?;
                stream.set_nodelay(true).ok();
                return Ok(stream);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if start.elapsed() > deadline {
                    return Err(anyhow!("timed out accepting {what}"));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow!("accepting {what}: {e}")),
        }
    }
}

/// Read one frame with a bounded wait (used only during handshakes;
/// steady-state reads run nonblocking under the poller).
pub(crate) fn read_frame_timed(stream: &mut TcpStream, what: &str) -> Result<WireMsg> {
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    let msg = read_frame(stream).with_context(|| format!("reading {what}"))?;
    stream.set_read_timeout(None)?;
    Ok(msg)
}

/// Mint a leader-issued worker identity token (`Init::token`).  Not a
/// secret — just an identifier distinct per (process, issue order) so a
/// stale replacement claiming an already-refilled shard is refused.
pub(crate) fn fresh_token(shard: usize) -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static ISSUED: AtomicU64 = AtomicU64::new(0);
    let seq = ISSUED.fetch_add(1, Ordering::Relaxed);
    Pcg64::new((u64::from(std::process::id()) << 32) ^ seq).next_u64() ^ shard as u64
}

// ---------------------------------------------------------------- leader

/// The leader's bound-but-not-yet-accepting socket.  Binding is split
/// from accepting so callers can learn the ephemeral port (and hand it
/// to worker processes) before [`Cluster::spawn_tcp`] blocks in the
/// handshake.
///
/// [`Cluster::spawn_tcp`]: crate::coordinator::Cluster::spawn_tcp
pub struct LeaderListener {
    listener: TcpListener,
}

impl LeaderListener {
    /// Bind the leader socket (e.g. `"127.0.0.1:0"` for an ephemeral
    /// loopback port).
    pub fn bind(addr: &str) -> Result<LeaderListener> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding leader socket {addr}"))?;
        Ok(LeaderListener { listener })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Surrender the raw socket (the tiered leader accepts host
    /// processes on it instead of shard workers).
    pub(crate) fn into_inner(self) -> TcpListener {
        self.listener
    }
}

/// Initial state shipped to one worker in its `Init` frame.
pub struct InitPayload {
    /// First node id of the worker's contiguous shard.
    pub lo: usize,
    /// Algorithm to run, as its canonical `PairAlgorithm::name()`.
    pub algo: String,
    /// The shard's initial per-node load lists, in node order.
    pub nodes: Vec<Vec<Load>>,
}

/// The leader's TCP endpoint: one connected socket per worker, all
/// polled nonblocking from the leader's own thread.
pub struct TcpLeader {
    poller: Poller,
    /// Poller token per shard.
    tokens: Vec<usize>,
    /// Shard sent its terminal report (`Final`/`Error`, possibly
    /// synthesized from a lost connection); ignore anything further.
    done: Vec<bool>,
    /// Reports decoded but not yet handed to the caller.
    queue: VecDeque<Report>,
    events: VecDeque<Event>,
    /// The accept socket, retained past the initial handshake so a
    /// replacement worker can dial in and rejoin (`--connect` clusters).
    listener: Option<TcpListener>,
    /// Worker listen addresses of a `--listen` cluster (`None` entries
    /// on accept-mode clusters): rejoin redials the restarted worker.
    dial_addrs: Vec<Option<String>>,
    /// Current peer-mesh listener address per shard; a reassigned-away
    /// shard's entry is cleared so a later rejoiner knows not to expect
    /// a mesh connection from it.
    peer_addrs: Vec<String>,
    /// Original first-node id per shard (informational in a rejoin
    /// `Init`: the rejoiner's state arrives via `Ctl::OpenJob`).
    los: Vec<usize>,
    /// Algorithm name shipped in every `Init`.
    algo: String,
    /// Identity token issued to the current occupant of each shard.
    idents: Vec<u64>,
}

impl TcpLeader {
    /// Accept `inits.len()` workers on `listener`, then complete the
    /// handshake (collect `Hello`s, send `Init`s, register the sockets
    /// with the poller).  The listener stays open afterwards so
    /// replacement workers can rejoin ([`await_rejoin`]).
    ///
    /// [`await_rejoin`]: LeaderTransport::await_rejoin
    pub fn accept(listener: LeaderListener, inits: Vec<InitPayload>) -> Result<TcpLeader> {
        let k = inits.len();
        let mut conns = Vec::with_capacity(k);
        for i in 0..k {
            let stream = accept_with_deadline(
                &listener.listener,
                HANDSHAKE_TIMEOUT,
                &format!("cluster worker {} of {k}", i + 1),
            )?;
            conns.push(stream);
        }
        Self::handshake(conns, inits, Some(listener.listener), vec![None; k])
    }

    /// Dial one listening worker per address (workers started with
    /// `cluster-worker --listen`), then complete the handshake.  Worker
    /// `i` of `addrs` becomes shard `i`; a dead worker restarted on the
    /// same address can be redialed for rejoin.
    pub fn connect(addrs: &[String], inits: Vec<InitPayload>) -> Result<TcpLeader> {
        assert_eq!(addrs.len(), inits.len(), "one address per shard");
        let mut conns = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let stream = connect_with_retry(addr, DEFAULT_CONNECT_RETRIES)
                .with_context(|| format!("dialing cluster worker {addr}"))?;
            conns.push(stream);
        }
        let dials = addrs.iter().map(|a| Some(a.clone())).collect();
        Self::handshake(conns, inits, None, dials)
    }

    fn handshake(
        mut conns: Vec<TcpStream>,
        inits: Vec<InitPayload>,
        listener: Option<TcpListener>,
        dial_addrs: Vec<Option<String>>,
    ) -> Result<TcpLeader> {
        let k = conns.len();
        // collect every worker's peer-mesh address (a rejoin claim in a
        // first handshake is meaningless and ignored)
        let mut peer_addrs = Vec::with_capacity(k);
        for (i, stream) in conns.iter_mut().enumerate() {
            match read_frame_timed(stream, &format!("Hello from worker {i}"))? {
                WireMsg::Hello { peer_addr, rejoin: _ } => peer_addrs.push(peer_addr),
                other => {
                    return Err(anyhow!(
                        "worker {i} handshake: expected Hello, got {other:?}"
                    ))
                }
            }
        }
        let los: Vec<usize> = inits.iter().map(|i| i.lo).collect();
        let algo = inits.first().map(|i| i.algo.clone()).unwrap_or_default();
        let mut idents = Vec::with_capacity(k);
        // ship each worker its identity, initial nodes, and the mesh map
        for (shard, (stream, init)) in conns.iter_mut().zip(inits).enumerate() {
            let token = fresh_token(shard);
            idents.push(token);
            let msg = WireMsg::Init(Init {
                shard,
                shards: k,
                lo: init.lo,
                algo: init.algo,
                nodes: init.nodes,
                peers: peer_addrs.clone(),
                rejoin: false,
                resume_round: 0,
                token,
            });
            write_frame(stream, &msg)
                .with_context(|| format!("sending Init to worker {shard}"))?;
        }
        // hand every socket to the poller; from here on the leader
        // thread is the only reader and writer
        let mut poller = Poller::new();
        let mut tokens = Vec::with_capacity(k);
        for stream in conns {
            tokens.push(
                poller
                    .add_frame_conn(stream)
                    .context("registering a worker socket")?,
            );
        }
        Ok(TcpLeader {
            poller,
            tokens,
            done: vec![false; k],
            queue: VecDeque::new(),
            events: VecDeque::new(),
            listener,
            dial_addrs,
            peer_addrs,
            los,
            algo,
            idents,
        })
    }

    /// Complete a replacement worker's rejoin handshake on an
    /// established connection: read its `Hello`, validate any identity
    /// claim, send a rejoin `Init`, and splice the socket into the dead
    /// shard's slot.  Returns the replacement's peer-listener address.
    fn rehandshake(
        &mut self,
        mut stream: TcpStream,
        shard: usize,
        resume_round: usize,
    ) -> Result<String> {
        let (peer_addr, claim) =
            match read_frame_timed(&mut stream, "Hello from a rejoining worker")? {
                WireMsg::Hello { peer_addr, rejoin } => (peer_addr, rejoin),
                other => return Err(anyhow!("rejoin handshake: expected Hello, got {other:?}")),
            };
        if let Some(tok) = claim {
            if tok != self.idents[shard] {
                return Err(anyhow!(
                    "rejoin handshake: stale identity token for shard {shard}"
                ));
            }
        }
        let token = fresh_token(shard);
        self.peer_addrs[shard] = peer_addr.clone();
        let msg = WireMsg::Init(Init {
            shard,
            shards: self.tokens.len(),
            lo: self.los[shard],
            algo: self.algo.clone(),
            // the rejoiner's load slice arrives via Ctl::OpenJob with
            // the checkpoint; the Init ships only identity and topology
            nodes: Vec::new(),
            peers: self.peer_addrs.clone(),
            rejoin: true,
            resume_round,
            token,
        });
        write_frame(&mut stream, &msg).context("sending rejoin Init")?;
        self.poller.remove(self.tokens[shard]);
        let tok = self
            .poller
            .add_frame_conn(stream)
            .context("registering the rejoined worker socket")?;
        self.tokens[shard] = tok;
        self.done[shard] = false;
        self.idents[shard] = token;
        Ok(peer_addr)
    }

    fn shard_of(&self, token: usize) -> Option<usize> {
        self.tokens.iter().position(|&t| t == token)
    }

    /// Turn one poller event into zero or more queued reports.  A
    /// connection loss is synthesized into a `Report::Error` naming the
    /// shard, so a killed worker process trips the leader's fail-stop
    /// path instead of a bare timeout.  After a `Final` or an `Error`
    /// the worker is done by protocol, so the inevitable EOF that
    /// follows is *not* reported as a failure.
    fn absorb(&mut self, ev: Event) {
        match ev {
            Event::Frame { token, msg } => {
                let Some(shard) = self.shard_of(token) else {
                    return;
                };
                if self.done[shard] {
                    return;
                }
                match msg {
                    WireMsg::Report(report) => {
                        // A `Final` or an *untagged* error ends the
                        // worker's lifecycle by protocol.  A job-tagged
                        // error only retires that job: the worker stays
                        // connected (it may serve other tenants, or the
                        // recovered epoch that replaces the failed one).
                        let terminal = match &report {
                            Report::Final { .. } => true,
                            Report::Error { job, .. } => job.is_none(),
                            _ => false,
                        };
                        if terminal {
                            self.done[shard] = true;
                            self.poller.set_done(token);
                        }
                        self.queue.push_back(report);
                    }
                    other => {
                        self.done[shard] = true;
                        self.poller.set_done(token);
                        self.queue.push_back(Report::Error {
                            job: None,
                            shard,
                            round: None,
                            message: format!("protocol violation: unexpected frame {other:?}"),
                        });
                    }
                }
            }
            Event::Closed { token, reason } => {
                let Some(shard) = self.shard_of(token) else {
                    return;
                };
                if self.done[shard] {
                    return;
                }
                self.done[shard] = true;
                self.queue.push_back(Report::Error {
                    job: None,
                    shard,
                    round: None,
                    message: format!("worker connection lost: {reason}"),
                });
            }
            _ => {}
        }
    }
}

impl LeaderTransport for TcpLeader {
    fn shards(&self) -> usize {
        self.tokens.len()
    }

    fn send_ctl(&mut self, shard: usize, msg: Ctl) -> Result<(), TransportError> {
        // A worker only ever reads its own slice of each plan
        // (`per_shard[shard]`), so strip the other shards' entries
        // before serializing: leader egress stays O(plan bytes) per
        // batch instead of O(k x plan bytes).  The local backend keeps
        // the shared Arc table untouched (zero-copy anyway).
        let msg = match msg {
            Ctl::RunBatch {
                job,
                start_round,
                rounds,
                seed,
                plans,
                checkpoint,
            } => {
                let sliced: Vec<Arc<RoundPlan>> = plans
                    .iter()
                    .map(|p| {
                        let mut per_shard = vec![ShardPlan::default(); p.per_shard.len()];
                        per_shard[shard] = p.per_shard[shard].clone();
                        Arc::new(RoundPlan {
                            per_shard,
                            cross_edges: p.cross_edges,
                            edges: p.edges,
                        })
                    })
                    .collect();
                Ctl::RunBatch {
                    job,
                    start_round,
                    rounds,
                    seed,
                    plans: Arc::new(sliced),
                    checkpoint,
                }
            }
            other => other,
        };
        self.poller
            .send(self.tokens[shard], &WireMsg::Ctl(msg))
            .map_err(|e| {
                TransportError::Closed(format!("worker {shard} connection closed: {e}"))
            })
    }

    fn recv_report(&mut self, wait: Duration) -> Result<Report, TransportError> {
        let deadline = Instant::now() + wait;
        loop {
            if let Some(r) = self.queue.pop_front() {
                return Ok(r);
            }
            if self.done.iter().all(|&d| d) {
                return Err(TransportError::Closed(
                    "all cluster worker connections closed".to_string(),
                ));
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(TransportError::Timeout);
            }
            self.poller.poll(deadline - now, &mut self.events);
            while let Some(ev) = self.events.pop_front() {
                self.absorb(ev);
            }
        }
    }

    fn await_rejoin(
        &mut self,
        shard: usize,
        resume_round: usize,
        wait: Duration,
    ) -> Result<Option<String>, TransportError> {
        let deadline = Instant::now() + wait;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let remaining = deadline - now;
            // accept-mode clusters wait for the replacement to dial in;
            // connect-mode clusters redial the restarted worker's
            // listen address
            let stream = if let Some(listener) = &self.listener {
                match accept_with_deadline(listener, remaining, "a rejoining worker") {
                    Ok(s) => s,
                    Err(_) => return Ok(None),
                }
            } else if let Some(addr) = self.dial_addrs[shard].clone() {
                let retries =
                    (remaining.as_millis() / CONNECT_RETRY_DELAY.as_millis()).max(1) as usize;
                match connect_with_retry(&addr, retries) {
                    Ok(s) => s,
                    Err(_) => return Ok(None),
                }
            } else {
                return Ok(None);
            };
            // a malformed or stale claimant burns its connection, not
            // the window: keep listening until the deadline
            match self.rehandshake(stream, shard, resume_round) {
                Ok(addr) => return Ok(Some(addr)),
                Err(_) => continue,
            }
        }
    }
}

// ---------------------------------------------------------------- worker

enum CtlEvent {
    Msg(Box<Ctl>),
    Gone(String),
}

enum PeerEvent {
    Msg(ShardMsg),
    Gone { peer: usize, reason: String },
}

/// A worker's TCP endpoint: the leader socket plus one mesh socket per
/// peer shard, all polled nonblocking from the worker's own thread.
///
/// Any blocked receive drains **all** connections: control frames
/// arriving while the worker waits for peer traffic (and vice versa)
/// queue up instead of stalling the sender, which is what lets a fast
/// shard run ahead within a batch.
pub struct TcpWorker {
    shard: usize,
    shards_total: usize,
    poller: Poller,
    leader_tok: usize,
    /// Poller token per peer shard (`None` for self / no link).
    peer_toks: Vec<Option<usize>>,
    ctl_q: VecDeque<CtlEvent>,
    peer_q: VecDeque<PeerEvent>,
    events: VecDeque<Event>,
}

impl TcpWorker {
    fn peer_of(&self, token: usize) -> Option<usize> {
        self.peer_toks.iter().position(|&t| t == Some(token))
    }

    /// Route one poller event to the control or peer queue.
    fn absorb(&mut self, ev: Event) {
        match ev {
            Event::Frame { token, msg } if token == self.leader_tok => match msg {
                WireMsg::Ctl(ctl) => {
                    if matches!(ctl, Ctl::Shutdown) {
                        // the leader closes the socket after Shutdown;
                        // that EOF is expected, not a failure
                        self.poller.set_done(self.leader_tok);
                    }
                    self.ctl_q.push_back(CtlEvent::Msg(Box::new(ctl)));
                }
                other => {
                    self.poller.set_done(self.leader_tok);
                    self.ctl_q.push_back(CtlEvent::Gone(format!(
                        "protocol violation: unexpected frame from leader: {other:?}"
                    )));
                }
            },
            Event::Frame { token, msg } => {
                let Some(peer) = self.peer_of(token) else {
                    return;
                };
                match msg {
                    WireMsg::Peer(m) => self.peer_q.push_back(PeerEvent::Msg(m)),
                    other => {
                        self.poller.set_done(token);
                        self.peer_q.push_back(PeerEvent::Gone {
                            peer,
                            reason: format!("protocol violation: unexpected frame {other:?}"),
                        });
                    }
                }
            }
            Event::Closed { token, reason } => {
                if token == self.leader_tok {
                    self.ctl_q
                        .push_back(CtlEvent::Gone(format!("leader connection lost: {reason}")));
                } else if let Some(peer) = self.peer_of(token) {
                    self.peer_q.push_back(PeerEvent::Gone { peer, reason });
                }
            }
            _ => {}
        }
    }

    fn pump(&mut self, wait: Duration) {
        self.poller.poll(wait, &mut self.events);
        while let Some(ev) = self.events.pop_front() {
            self.absorb(ev);
        }
    }

    /// All mesh links down (or none ever existed) with nothing queued —
    /// the poller equivalent of the old "every peer reader exited".
    fn peers_gone(&self) -> bool {
        self.peer_toks
            .iter()
            .all(|t| t.map_or(true, |tok| self.poller.is_closed(tok)))
    }
}

impl WorkerTransport for TcpWorker {
    fn shard(&self) -> usize {
        self.shard
    }

    fn shards(&self) -> usize {
        self.shards_total
    }

    fn recv_ctl(&mut self) -> Result<Ctl, TransportError> {
        loop {
            match self.ctl_q.pop_front() {
                Some(CtlEvent::Msg(c)) => return Ok(*c),
                Some(CtlEvent::Gone(reason)) => return Err(TransportError::Closed(reason)),
                None => {}
            }
            if self.poller.is_closed(self.leader_tok) {
                return Err(TransportError::Closed(
                    "leader connection closed".to_string(),
                ));
            }
            self.pump(Duration::from_millis(100));
        }
    }

    fn send_report(&mut self, msg: Report) -> Result<(), TransportError> {
        self.poller
            .send(self.leader_tok, &WireMsg::Report(msg))
            .map_err(|e| TransportError::Closed(format!("leader connection closed: {e}")))
    }

    fn send_peer(&mut self, peer: usize, msg: ShardMsg) -> Result<(), TransportError> {
        let token = self.peer_toks[peer]
            .ok_or_else(|| TransportError::Closed(format!("no mesh link to shard {peer}")))?;
        self.poller.send(token, &WireMsg::Peer(msg)).map_err(|e| {
            TransportError::Closed(format!("peer shard {peer} connection closed: {e}"))
        })
    }

    fn recv_peer(&mut self, wait: Duration) -> Result<ShardMsg, TransportError> {
        let deadline = Instant::now() + wait;
        loop {
            match self.peer_q.pop_front() {
                Some(PeerEvent::Msg(m)) => return Ok(m),
                Some(PeerEvent::Gone { peer, reason }) => {
                    return Err(TransportError::Closed(format!(
                        "peer shard {peer} disconnected: {reason}"
                    )))
                }
                None => {}
            }
            if self.peers_gone() {
                return Err(TransportError::Closed(
                    "all peer connections closed".to_string(),
                ));
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(TransportError::Timeout);
            }
            self.pump(deadline - now);
        }
    }

    fn remesh_peer(&mut self, shard: usize, addr: &str) -> Result<(), TransportError> {
        // drop the dead link and purge its queued loss events either
        // way; an empty address means the shard was reassigned away and
        // no replacement link exists
        if let Some(old) = self.peer_toks[shard].take() {
            self.poller.remove(old);
        }
        self.peer_q
            .retain(|e| !matches!(e, PeerEvent::Gone { peer, .. } if *peer == shard));
        if addr.is_empty() {
            return Ok(());
        }
        let mut stream = connect_with_retry(addr, DEFAULT_CONNECT_RETRIES).map_err(|e| {
            TransportError::Closed(format!("dialing rejoined shard {shard} at {addr}: {e}"))
        })?;
        write_frame(&mut stream, &WireMsg::PeerHello { shard: self.shard }).map_err(|e| {
            TransportError::Closed(format!("greeting rejoined shard {shard}: {e}"))
        })?;
        let tok = self.poller.add_frame_conn(stream).map_err(|e| {
            TransportError::Closed(format!("registering the rejoined peer socket: {e}"))
        })?;
        self.peer_toks[shard] = Some(tok);
        Ok(())
    }
}

/// Everything a worker process learned from its `Init` frame, needed to
/// install the bootstrap job (job 0) on the [`ShardWorker`] — or, on a
/// rejoin, to skip that install and wait for the recovery `OpenJob`.
pub struct WorkerSeed {
    /// Assigned shard index.
    pub shard: usize,
    /// Total shard count.
    pub shards: usize,
    /// First node id of the shard.
    pub lo: usize,
    /// Algorithm name (`PairAlgorithm::parse` spelling).
    pub algo: String,
    /// Initial per-node load lists (empty on a rejoin: the recovered
    /// slice arrives via `Ctl::OpenJob` with the checkpoint).
    pub nodes: Vec<Vec<Load>>,
    /// This handshake re-admitted the worker into a running cluster.
    pub rejoin: bool,
    /// Round the recovered epoch resumes from (informational).
    pub resume_round: usize,
}

/// Complete a worker's side of the handshake after the leader's `Init`
/// arrived (`Hello` already sent, peer listener already bound — see
/// [`serve`]): build the mesh and register every socket with the
/// worker's poller.
///
/// A rejoin `Init` inverts the mesh bootstrap: the survivors are told to
/// dial the rejoiner (`Ctl::Remesh`), so the rejoiner dials nobody and
/// accepts one connection per *live* peer (the `Init` peer table marks
/// reassigned-away shards with an empty address).
fn worker_handshake(
    leader: TcpStream,
    peer_listener: TcpListener,
    init: Init,
) -> Result<(TcpWorker, WorkerSeed)> {
    let (me, k) = (init.shard, init.shards);
    if me >= k || init.peers.len() != k {
        return Err(anyhow!(
            "handshake: inconsistent Init (shard {me} of {k}, {} peers)",
            init.peers.len()
        ));
    }
    let mut peers: Vec<Option<TcpStream>> = (0..k).map(|_| None).collect();
    if init.rejoin {
        // rejoin mesh: every live survivor dials us (driven by the
        // leader's Ctl::Remesh); reassigned-away shards have an empty
        // peer-table entry and no link
        let expected = init
            .peers
            .iter()
            .enumerate()
            .filter(|&(p, a)| p != me && !a.is_empty())
            .count();
        for _ in 0..expected {
            let mut stream = accept_with_deadline(
                &peer_listener,
                HANDSHAKE_TIMEOUT,
                "a remeshing survivor",
            )?;
            match read_frame_timed(&mut stream, "PeerHello")? {
                WireMsg::PeerHello { shard }
                    if shard < k && shard != me && peers[shard].is_none() =>
                {
                    peers[shard] = Some(stream);
                }
                WireMsg::PeerHello { shard } => {
                    return Err(anyhow!("remesh: unexpected PeerHello from shard {shard}"))
                }
                other => return Err(anyhow!("remesh: expected PeerHello, got {other:?}")),
            }
        }
    } else {
        // first mesh: dial every lower shard, accept every higher one,
        // so each unordered pair of shards shares exactly one socket
        for (p, addr) in init.peers.iter().enumerate().take(me) {
            let mut stream = connect_with_retry(addr, DEFAULT_CONNECT_RETRIES)
                .with_context(|| format!("dialing peer shard {p} at {addr}"))?;
            write_frame(&mut stream, &WireMsg::PeerHello { shard: me })
                .with_context(|| format!("greeting peer shard {p}"))?;
            peers[p] = Some(stream);
        }
        for _ in me + 1..k {
            let mut stream = accept_with_deadline(
                &peer_listener,
                HANDSHAKE_TIMEOUT,
                "a peer-mesh connection",
            )?;
            match read_frame_timed(&mut stream, "PeerHello")? {
                WireMsg::PeerHello { shard }
                    if shard < k && shard > me && peers[shard].is_none() =>
                {
                    peers[shard] = Some(stream);
                }
                WireMsg::PeerHello { shard } => {
                    return Err(anyhow!("mesh: unexpected PeerHello from shard {shard}"))
                }
                other => return Err(anyhow!("mesh: expected PeerHello, got {other:?}")),
            }
        }
    }
    // every socket goes nonblocking under one poller; the worker thread
    // is its own reader from here on
    let mut poller = Poller::new();
    let leader_tok = poller
        .add_frame_conn(leader)
        .context("registering the leader socket")?;
    let mut peer_toks: Vec<Option<usize>> = (0..k).map(|_| None).collect();
    for (p, slot) in peers.into_iter().enumerate() {
        if let Some(stream) = slot {
            peer_toks[p] = Some(
                poller
                    .add_frame_conn(stream)
                    .context("registering a peer socket")?,
            );
        }
    }
    let transport = TcpWorker {
        shard: me,
        shards_total: k,
        poller,
        leader_tok,
        peer_toks,
        ctl_q: VecDeque::new(),
        peer_q: VecDeque::new(),
        events: VecDeque::new(),
    };
    let seed = WorkerSeed {
        shard: init.shard,
        shards: init.shards,
        lo: init.lo,
        algo: init.algo,
        nodes: init.nodes,
        rejoin: init.rejoin,
        resume_round: init.resume_round,
    };
    Ok((transport, seed))
}

// ------------------------------------------------------- worker process

/// Serve one cluster run as a worker process, dialing the leader at
/// `addr` (the `bcm-dlb cluster-worker --connect` entry point).
/// Returns after the cluster shuts down.  `fault_exit` is the hidden
/// `--fault-exit` recovery-test hook: hard-exit the process at the
/// start of that global round.  `pin` requests best-effort core pinning
/// of in-process shard workers (two-tier clusters only; a flat shard
/// worker ignores it).
pub fn serve_connect(
    addr: &str,
    retries: usize,
    fault_exit: Option<usize>,
    pin: bool,
) -> Result<()> {
    let leader = connect_with_retry(addr, retries)
        .with_context(|| format!("connecting to cluster leader {addr}"))?;
    serve(leader, fault_exit, pin)
}

/// Serve one cluster run as a worker process, listening on `addr` for
/// the leader's dial-in (the `bcm-dlb cluster-worker --listen` entry
/// point, paired with the leader's `peers` list).
pub fn serve_listen(addr: &str, fault_exit: Option<usize>, pin: bool) -> Result<()> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding worker socket {addr}"))?;
    let leader = accept_with_deadline(&listener, HANDSHAKE_TIMEOUT, "the cluster leader")?;
    serve(leader, fault_exit, pin)
}

/// The worker process's role is decided by the leader, not a flag: bind
/// the mesh listener, send `Hello`, and let the init frame's kind pick
/// the path — a flat `Init` makes this process one shard worker, a
/// `HostInit` makes it a whole two-tier host (the listener then serves
/// as the *host*-mesh accept socket).
fn serve(mut leader: TcpStream, fault_exit: Option<usize>, pin: bool) -> Result<()> {
    leader.set_nodelay(true).ok();
    // the mesh listener lives on whatever interface reaches the leader
    let ip = leader.local_addr()?.ip();
    let peer_listener =
        TcpListener::bind((ip, 0)).context("binding the worker's peer-mesh listener")?;
    let my_addr = peer_listener.local_addr()?.to_string();
    write_frame(
        &mut leader,
        &WireMsg::Hello {
            peer_addr: my_addr,
            rejoin: None,
        },
    )
    .context("sending Hello to the leader")?;
    let init = match read_frame_timed(&mut leader, "an init frame from the leader")? {
        WireMsg::Init(init) => init,
        WireMsg::HostInit(hi) => {
            return super::tiered::serve_host(leader, peer_listener, hi, fault_exit, pin)
        }
        other => return Err(anyhow!("handshake: expected Init, got {other:?}")),
    };
    let (transport, seed) = worker_handshake(leader, peer_listener, init)?;
    let algo = PairAlgorithm::parse(&seed.algo)
        .with_context(|| format!("leader sent unknown algorithm '{}'", seed.algo))?;
    if seed.rejoin {
        eprintln!(
            "cluster-worker: shard {}/{} rejoined, resuming from round {}",
            seed.shard, seed.shards, seed.resume_round
        );
    } else {
        eprintln!(
            "cluster-worker: shard {}/{} serving nodes {}..{}",
            seed.shard,
            seed.shards,
            seed.lo,
            seed.lo + seed.nodes.len()
        );
    }
    let mut worker = ShardWorker::new(Box::new(transport));
    if !seed.rejoin {
        worker.install_job(0, seed.lo, seed.nodes, algo);
    }
    if let Some(round) = fault_exit {
        worker.set_fault_exit(round);
    }
    // only a clean Ctl::Shutdown lifecycle exits 0 — scripts and
    // orchestrators keyed on the exit code must see failures
    worker
        .run()
        .map_err(|e| anyhow!("cluster-worker shard {} terminated abnormally: {e}", seed.shard))
}
