//! Sequential-vs-parallel engine speedup on the n >= 4096 topologies
//! (the §Perf deliverable of the deterministic parallel engine).
//!
//! Every parallel run is checked bit-identical against the sequential
//! reference before its time is reported, so this bench doubles as a
//! determinism smoke test.
//!
//! `cargo bench --bench hotpath_parallel` runs the full
//! `experiments::scaling::large_scenarios()` set; `-- --smoke` (or
//! `BCM_DLB_SMOKE=1` / `BCM_DLB_QUICK=1`) derates every scenario to
//! n=256, 1 sweep, so CI can exercise the harness in seconds.

use bcm_dlb::experiments::scaling::{large_scenarios, run_scaling, scaling_table};
use bcm_dlb::util::table::f;
use std::path::Path;

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || env_flag("BCM_DLB_SMOKE")
        || env_flag("BCM_DLB_QUICK");
    let thread_ladder = [2usize, 4, 0]; // 0 = auto (one worker per core)
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    eprintln!(
        "hotpath_parallel: {} scenarios, {cores} cores{}",
        large_scenarios().len(),
        if smoke { " (smoke: n=256, 1 sweep)" } else { "" }
    );

    let start = std::time::Instant::now();
    let mut diverged = false;
    let mut best_overall: f64 = 0.0;
    for scenario in large_scenarios() {
        // Smoke mode keeps the scenario set but shrinks every instance
        // (all four topologies build at n=256: 2^8, 16^2, 4*8*8, d=8).
        let (n, loads, sweeps) = if smoke {
            (256, 10, 1)
        } else {
            (scenario.n, scenario.loads_per_node, 2)
        };
        let report =
            run_scaling(&scenario.topology, n, loads, sweeps, 2013, &thread_ladder, &[], &[])
                .expect("scaling run failed (no cluster rows requested)");
        let t = scaling_table(&report);
        println!("{}", t.render());
        t.write_csv(Path::new(&format!(
            "results/hotpath_parallel_{}.csv",
            scenario.name
        )))
        .ok();
        if !report.all_identical() {
            eprintln!("DIVERGENCE: {} parallel != sequential", scenario.name);
            diverged = true;
        }
        best_overall = best_overall.max(report.best_speedup());
    }
    eprintln!(
        "hotpath_parallel completed in {:.1}s; best speedup {}x",
        start.elapsed().as_secs_f64(),
        f(best_overall, 2)
    );
    if diverged {
        std::process::exit(1);
    }
}
