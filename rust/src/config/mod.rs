//! Experiment configuration: JSON files <-> typed config.
//!
//! Used by the CLI launcher (`bcm-dlb run --config exp.json`) so paper
//! sweeps and ad-hoc experiments share one schema.

use crate::balancer::PairAlgorithm;
use crate::coordinator::transport::TransportKind;
use crate::graph::Topology;
use crate::load::{Mobility, WeightDistribution};
use crate::anyhow;
use crate::util::error::Result;
use crate::util::json::Json;
use crate::workload::service_traffic::TrafficConfig;

/// The only dynamic workload currently understood by `workload` /
/// `--workload`.
pub const WORKLOAD_SERVICE_TRAFFIC: &str = "service-traffic";

/// One protocol experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub topology: Topology,
    pub n: usize,
    pub loads_per_node: usize,
    pub distribution: WeightDistribution,
    pub mobility: Mobility,
    pub algorithm: PairAlgorithm,
    pub sweeps: usize,
    pub reps: usize,
    pub seed: u64,
    /// Use the PJRT device path when artifacts are available.
    pub use_device: bool,
    /// Engine worker threads: 1 = sequential reference engine, 0 = one
    /// worker per core, k > 1 = the deterministic parallel engine with k
    /// workers.  Results are bit-identical across all values.
    pub threads: usize,
    /// Sharded-coordinator worker count (the `--cluster` path): 0 = one
    /// shard per core, k = exactly k shards (clamped to n).  Like
    /// `threads`, purely a performance knob — results are bit-identical
    /// across all values.
    pub shards: usize,
    /// Rounds dispatched per leader control message on the `--cluster`
    /// path: 0 = auto (`max(1, n / 16384)` — batch only once leader
    /// round-trips dominate), B = exactly B rounds per batch.  Purely a
    /// performance knob — results are bit-identical across all values.
    pub batch_rounds: usize,
    /// Cluster transport backend: `local` (in-process channels, the
    /// default) or `tcp` (workers are separate `cluster-worker`
    /// processes).  Results are bit-identical across backends.
    pub transport: TransportKind,
    /// Two-tier cluster host count (config key `hosts`, flag `--hosts`):
    /// `0` (the default) keeps the flat one-worker-per-shard cluster;
    /// `H > 0` runs the hierarchical coordinator — `H` cluster-worker
    /// *hosts*, each hosting [`shards_per_host`](Self::shards_per_host)
    /// in-process shard workers, with shards placed cut-aware
    /// (`ShardMap::partition_tiered`) so cross-host wire traffic scales
    /// with the inter-host cut.  Results are bit-identical to the flat
    /// cluster and to `bcm::Sequential` for any `H`.
    pub hosts: usize,
    /// In-process shard workers per host on the two-tier path (config
    /// key `shards_per_host`, flag `--shards-per-host`); `0` = one per
    /// core.  Only consulted when [`hosts`](Self::hosts) `> 0`.
    pub shards_per_host: usize,
    /// Leader bind address for `transport = tcp` (the `--listen` flag);
    /// workers dial in with `cluster-worker --connect`.
    pub listen: String,
    /// Worker addresses for `transport = tcp` when the leader dials out
    /// instead of listening (the `--peers` flag; workers run
    /// `cluster-worker --listen`).  Non-empty `peers` takes precedence
    /// over `listen`, and its length fixes the shard count.
    pub peers: Vec<String>,
    /// `bcm-dlb serve` bind address (config key `serve.listen`, flag
    /// `--listen`): where the multi-tenant balancer service accepts job
    /// specs.
    pub serve_listen: String,
    /// Maximum jobs `bcm-dlb serve` runs concurrently on its shard pool
    /// (config key `serve.max_jobs`, flag `--max-jobs`); further
    /// submissions queue until a slot frees.
    pub serve_max_jobs: usize,
    /// Cluster checkpoint cadence in rounds (config key
    /// `checkpoint_every`, flag `--checkpoint-every`): the leader asks
    /// every worker to stream back its shard's load state at the first
    /// batch boundary at least this many rounds past the previous
    /// checkpoint.  `0` (the default) disables checkpointing and keeps
    /// the classic fail-stop cluster: any worker failure aborts the
    /// run.  With a cadence set, a worker failure triggers the recovery
    /// contract (`DESIGN.md` §8, `OPERATIONS.md`) instead — results are
    /// bit-identical either way.
    pub checkpoint_every: usize,
    /// How long the leader waits for a restarted worker to rejoin a
    /// dead shard before reassigning its nodes to the survivors
    /// (config key `rejoin_wait_ms`, flag `--rejoin-wait`), in
    /// milliseconds.  `0` skips the rejoin window and reassigns
    /// immediately.  Only consulted when `checkpoint_every > 0`.
    pub rejoin_wait_ms: u64,
    /// Dynamic workload selector (config key `workload`, flag
    /// `--workload`).  `None` (the default) balances the classic static
    /// load set; [`WORKLOAD_SERVICE_TRAFFIC`] runs the churning
    /// service-traffic generator between rounds
    /// (`workload::service_traffic`) for `sweeps` full schedule sweeps.
    /// Results stay bit-identical across threads/shards/batch either
    /// way.
    pub workload: Option<String>,
    /// Override of [`TrafficConfig::arrival_rate`] (key/flag
    /// `arrival_rate` / `--arrival-rate`); only legal with a
    /// `workload`.
    pub arrival_rate: Option<f64>,
    /// Override of [`TrafficConfig::pareto_alpha`] (key/flag
    /// `pareto_alpha` / `--pareto-alpha`); only legal with a
    /// `workload`.
    pub pareto_alpha: Option<f64>,
    /// Override of [`TrafficConfig::hotspot_every`] (key/flag
    /// `hotspot_every` / `--hotspot-every`); only legal with a
    /// `workload`.
    pub hotspot_every: Option<usize>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            topology: Topology::RandomConnected,
            n: 32,
            loads_per_node: 50,
            distribution: WeightDistribution::paper_section6(),
            mobility: Mobility::Full,
            algorithm: PairAlgorithm::SortedGreedy(crate::balancer::SortAlgo::Quick),
            sweeps: 15,
            reps: 10,
            seed: 2013,
            use_device: false,
            threads: 1,
            shards: 0,
            batch_rounds: 0,
            transport: TransportKind::Local,
            hosts: 0,
            shards_per_host: 1,
            listen: "127.0.0.1:7411".to_string(),
            peers: Vec::new(),
            serve_listen: "127.0.0.1:7412".to_string(),
            serve_max_jobs: 4,
            checkpoint_every: 0,
            rejoin_wait_ms: 5000,
            workload: None,
            arrival_rate: None,
            pareto_alpha: None,
            hotspot_every: None,
        }
    }
}

impl ExperimentConfig {
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        let v = Json::parse(text).map_err(|e| anyhow!("config: {e}"))?;
        let mut cfg = Self::default();
        if let Some(s) = v.get("topology").as_str() {
            cfg.topology =
                Topology::parse(s).ok_or_else(|| anyhow!("bad topology '{s}'"))?;
        }
        if let Some(n) = v.get("n").as_usize() {
            cfg.n = n;
        }
        if let Some(x) = v.get("loads_per_node").as_usize() {
            cfg.loads_per_node = x;
        }
        if let Some(s) = v.get("distribution").as_str() {
            cfg.distribution = WeightDistribution::parse(s)
                .ok_or_else(|| anyhow!("bad distribution '{s}'"))?;
        }
        if let Some(s) = v.get("mobility").as_str() {
            cfg.mobility = Mobility::parse(s).ok_or_else(|| anyhow!("bad mobility '{s}'"))?;
        }
        if let Some(s) = v.get("algorithm").as_str() {
            cfg.algorithm =
                PairAlgorithm::parse(s).ok_or_else(|| anyhow!("bad algorithm '{s}'"))?;
        }
        if let Some(x) = v.get("sweeps").as_usize() {
            cfg.sweeps = x;
        }
        if let Some(x) = v.get("reps").as_usize() {
            cfg.reps = x;
        }
        if let Some(x) = v.get("seed").as_u64() {
            cfg.seed = x;
        }
        if let Some(b) = v.get("use_device").as_bool() {
            cfg.use_device = b;
        }
        if let Some(x) = v.get("threads").as_usize() {
            cfg.threads = x;
        }
        if let Some(x) = v.get("shards").as_usize() {
            cfg.shards = x;
        }
        if let Some(x) = v.get("batch_rounds").as_usize() {
            cfg.batch_rounds = x;
        }
        if let Some(s) = v.get("transport").as_str() {
            cfg.transport =
                TransportKind::parse(s).ok_or_else(|| anyhow!("bad transport '{s}'"))?;
        }
        if let Some(x) = v.get("hosts").as_usize() {
            cfg.hosts = x;
        }
        if let Some(x) = v.get("shards_per_host").as_usize() {
            cfg.shards_per_host = x;
        }
        if let Some(s) = v.get("listen").as_str() {
            cfg.listen = s.to_string();
        }
        if let Some(arr) = v.get("peers").as_arr() {
            cfg.peers = arr
                .iter()
                .map(|p| {
                    p.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("peers must be an array of strings"))
                })
                .collect::<Result<Vec<String>>>()?;
        }
        if let Some(x) = v.get("checkpoint_every").as_usize() {
            cfg.checkpoint_every = x;
        }
        if let Some(x) = v.get("rejoin_wait_ms").as_u64() {
            cfg.rejoin_wait_ms = x;
        }
        let serve = v.get("serve");
        if let Some(s) = serve.get("listen").as_str() {
            cfg.serve_listen = s.to_string();
        }
        if let Some(x) = serve.get("max_jobs").as_usize() {
            if x == 0 {
                return Err(anyhow!("config: serve.max_jobs must be >= 1"));
            }
            cfg.serve_max_jobs = x;
        }
        if let Some(s) = v.get("workload").as_str() {
            if s != WORKLOAD_SERVICE_TRAFFIC {
                return Err(anyhow!(
                    "config: unknown workload '{s}' (expected '{WORKLOAD_SERVICE_TRAFFIC}')"
                ));
            }
            cfg.workload = Some(s.to_string());
        }
        if let Some(x) = v.get("arrival_rate").as_f64() {
            cfg.arrival_rate = Some(x);
        }
        if let Some(x) = v.get("pareto_alpha").as_f64() {
            cfg.pareto_alpha = Some(x);
        }
        if let Some(x) = v.get("hotspot_every").as_usize() {
            cfg.hotspot_every = Some(x);
        }
        if cfg.n < 2 {
            return Err(anyhow!("config: n must be >= 2"));
        }
        if cfg.loads_per_node == 0 {
            return Err(anyhow!("config: loads_per_node must be >= 1"));
        }
        cfg.validate_workload()?;
        Ok(cfg)
    }

    /// Reject churn knobs without a workload, and knob values outside
    /// the generator's domain.  Invoked by every parse path; `main`
    /// re-invokes it after flag overlays.
    pub fn validate_workload(&self) -> Result<()> {
        if self.workload.is_none() {
            for (knob, set) in [
                ("arrival_rate", self.arrival_rate.is_some()),
                ("pareto_alpha", self.pareto_alpha.is_some()),
                ("hotspot_every", self.hotspot_every.is_some()),
            ] {
                if set {
                    return Err(anyhow!(
                        "config: {knob} requires workload '{WORKLOAD_SERVICE_TRAFFIC}'"
                    ));
                }
            }
            return Ok(());
        }
        let t = self.traffic().expect("workload is set");
        t.validate().map_err(|m| anyhow!("config: {m}"))
    }

    /// The resolved churn generator config: defaults overridden by the
    /// explicit knobs.  `None` when no `workload` is selected.
    pub fn traffic(&self) -> Option<TrafficConfig> {
        self.workload.as_deref()?;
        let mut t = TrafficConfig::default();
        if let Some(x) = self.arrival_rate {
            t.arrival_rate = x;
        }
        if let Some(x) = self.pareto_alpha {
            t.pareto_alpha = x;
        }
        if let Some(x) = self.hotspot_every {
            t.hotspot_every = x;
        }
        Some(t)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("topology", self.topology.name().into()),
            ("n", self.n.into()),
            ("loads_per_node", self.loads_per_node.into()),
            ("distribution", self.distribution.name().into()),
            ("mobility", self.mobility.name().into()),
            ("algorithm", self.algorithm.name().into()),
            ("sweeps", self.sweeps.into()),
            ("reps", self.reps.into()),
            ("seed", (self.seed as usize).into()),
            ("use_device", self.use_device.into()),
            ("threads", self.threads.into()),
            ("shards", self.shards.into()),
            ("batch_rounds", self.batch_rounds.into()),
            ("transport", self.transport.name().into()),
            ("hosts", self.hosts.into()),
            ("shards_per_host", self.shards_per_host.into()),
            ("checkpoint_every", self.checkpoint_every.into()),
            ("rejoin_wait_ms", (self.rejoin_wait_ms as usize).into()),
            ("listen", self.listen.clone().into()),
            (
                "peers",
                Json::Arr(self.peers.iter().map(|p| p.as_str().into()).collect()),
            ),
            (
                "serve",
                Json::obj(vec![
                    ("listen", self.serve_listen.clone().into()),
                    ("max_jobs", self.serve_max_jobs.into()),
                ]),
            ),
        ];
        // optional workload keys are omitted when unset so a static
        // config round-trips to a static config
        if let Some(w) = &self.workload {
            fields.push(("workload", w.clone().into()));
        }
        if let Some(x) = self.arrival_rate {
            fields.push(("arrival_rate", x.into()));
        }
        if let Some(x) = self.pareto_alpha {
            fields.push(("pareto_alpha", x.into()));
        }
        if let Some(x) = self.hotspot_every {
            fields.push(("hotspot_every", x.into()));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip() {
        let cfg = ExperimentConfig::default();
        let text = cfg.to_json().to_string();
        let back = ExperimentConfig::from_json_str(&text).unwrap();
        assert_eq!(back.n, cfg.n);
        assert_eq!(back.topology, cfg.topology);
        assert_eq!(back.algorithm, cfg.algorithm);
        assert_eq!(back.mobility, cfg.mobility);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.threads, cfg.threads);
        assert_eq!(back.shards, cfg.shards);
    }

    #[test]
    fn threads_parse_and_default() {
        let cfg = ExperimentConfig::from_json_str(r#"{"threads": 8}"#).unwrap();
        assert_eq!(cfg.threads, 8);
        let cfg = ExperimentConfig::from_json_str(r#"{"threads": 0}"#).unwrap();
        assert_eq!(cfg.threads, 0); // 0 = auto
        let cfg = ExperimentConfig::from_json_str("{}").unwrap();
        assert_eq!(cfg.threads, 1);
    }

    #[test]
    fn shards_parse_and_default() {
        let cfg = ExperimentConfig::from_json_str(r#"{"shards": 4}"#).unwrap();
        assert_eq!(cfg.shards, 4);
        let cfg = ExperimentConfig::from_json_str("{}").unwrap();
        assert_eq!(cfg.shards, 0); // 0 = one shard per core
    }

    #[test]
    fn batch_rounds_parse_roundtrip_and_default() {
        let cfg = ExperimentConfig::from_json_str(r#"{"batch_rounds": 8}"#).unwrap();
        assert_eq!(cfg.batch_rounds, 8);
        let cfg = ExperimentConfig::from_json_str("{}").unwrap();
        assert_eq!(cfg.batch_rounds, 0); // 0 = auto (max(1, n / 16384))
        let text = cfg.to_json().to_string();
        assert!(text.contains("\"batch_rounds\":0"), "not serialized: {text}");
        let back = ExperimentConfig::from_json_str(&text).unwrap();
        assert_eq!(back.batch_rounds, cfg.batch_rounds);
    }

    #[test]
    fn transport_keys_parse_roundtrip_and_default() {
        let cfg = ExperimentConfig::from_json_str("{}").unwrap();
        assert_eq!(cfg.transport, TransportKind::Local);
        assert!(cfg.peers.is_empty());
        assert!(!cfg.listen.is_empty());
        let cfg = ExperimentConfig::from_json_str(
            r#"{"transport": "tcp", "listen": "0.0.0.0:9000",
                "peers": ["10.0.0.1:7411", "10.0.0.2:7411"]}"#,
        )
        .unwrap();
        assert_eq!(cfg.transport, TransportKind::Tcp);
        assert_eq!(cfg.listen, "0.0.0.0:9000");
        assert_eq!(cfg.peers, vec!["10.0.0.1:7411", "10.0.0.2:7411"]);
        let text = cfg.to_json().to_string();
        let back = ExperimentConfig::from_json_str(&text).unwrap();
        assert_eq!(back.transport, cfg.transport);
        assert_eq!(back.listen, cfg.listen);
        assert_eq!(back.peers, cfg.peers);
        assert!(ExperimentConfig::from_json_str(r#"{"transport": "udp"}"#).is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"peers": [42]}"#).is_err());
    }

    #[test]
    fn serve_keys_parse_roundtrip_and_default() {
        let cfg = ExperimentConfig::from_json_str("{}").unwrap();
        assert_eq!(cfg.serve_listen, "127.0.0.1:7412");
        assert_eq!(cfg.serve_max_jobs, 4);
        let cfg = ExperimentConfig::from_json_str(
            r#"{"serve": {"listen": "0.0.0.0:8100", "max_jobs": 2}}"#,
        )
        .unwrap();
        assert_eq!(cfg.serve_listen, "0.0.0.0:8100");
        assert_eq!(cfg.serve_max_jobs, 2);
        let text = cfg.to_json().to_string();
        let back = ExperimentConfig::from_json_str(&text).unwrap();
        assert_eq!(back.serve_listen, cfg.serve_listen);
        assert_eq!(back.serve_max_jobs, cfg.serve_max_jobs);
        assert!(ExperimentConfig::from_json_str(r#"{"serve": {"max_jobs": 0}}"#).is_err());
    }

    #[test]
    fn tier_keys_parse_roundtrip_and_default() {
        let cfg = ExperimentConfig::from_json_str("{}").unwrap();
        assert_eq!(cfg.hosts, 0); // 0 = flat cluster, no second tier
        assert_eq!(cfg.shards_per_host, 1);
        let cfg =
            ExperimentConfig::from_json_str(r#"{"hosts": 3, "shards_per_host": 4}"#).unwrap();
        assert_eq!(cfg.hosts, 3);
        assert_eq!(cfg.shards_per_host, 4);
        let text = cfg.to_json().to_string();
        let back = ExperimentConfig::from_json_str(&text).unwrap();
        assert_eq!(back.hosts, cfg.hosts);
        assert_eq!(back.shards_per_host, cfg.shards_per_host);
    }

    #[test]
    fn recovery_keys_parse_roundtrip_and_default() {
        let cfg = ExperimentConfig::from_json_str("{}").unwrap();
        assert_eq!(cfg.checkpoint_every, 0); // 0 = off, classic fail-stop
        assert_eq!(cfg.rejoin_wait_ms, 5000);
        let cfg = ExperimentConfig::from_json_str(
            r#"{"checkpoint_every": 32, "rejoin_wait_ms": 250}"#,
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_every, 32);
        assert_eq!(cfg.rejoin_wait_ms, 250);
        let text = cfg.to_json().to_string();
        let back = ExperimentConfig::from_json_str(&text).unwrap();
        assert_eq!(back.checkpoint_every, cfg.checkpoint_every);
        assert_eq!(back.rejoin_wait_ms, cfg.rejoin_wait_ms);
    }

    #[test]
    fn partial_overrides() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{"n": 64, "algorithm": "greedy", "mobility": "partial"}"#,
        )
        .unwrap();
        assert_eq!(cfg.n, 64);
        assert_eq!(cfg.algorithm, PairAlgorithm::Greedy);
        assert_eq!(cfg.mobility, Mobility::Partial);
        assert_eq!(cfg.loads_per_node, 50); // default preserved
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ExperimentConfig::from_json_str(r#"{"topology": "moebius"}"#).is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"n": 1}"#).is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"loads_per_node": 0}"#).is_err());
        assert!(ExperimentConfig::from_json_str("not json").is_err());
    }

    #[test]
    fn workload_keys_parse_roundtrip_and_default() {
        let cfg = ExperimentConfig::from_json_str("{}").unwrap();
        assert!(cfg.workload.is_none());
        assert!(cfg.traffic().is_none());
        let cfg = ExperimentConfig::from_json_str(
            r#"{"workload": "service-traffic", "arrival_rate": 2.5,
                "pareto_alpha": 1.5, "hotspot_every": 16}"#,
        )
        .unwrap();
        assert_eq!(cfg.workload.as_deref(), Some(WORKLOAD_SERVICE_TRAFFIC));
        let t = cfg.traffic().unwrap();
        assert_eq!(t.arrival_rate, 2.5);
        assert_eq!(t.pareto_alpha, 1.5);
        assert_eq!(t.hotspot_every, 16);
        // unset knobs keep the generator defaults
        assert_eq!(t.depart_rate, TrafficConfig::default().depart_rate);
        let text = cfg.to_json().to_string();
        let back = ExperimentConfig::from_json_str(&text).unwrap();
        assert_eq!(back.workload, cfg.workload);
        assert_eq!(back.arrival_rate, cfg.arrival_rate);
        assert_eq!(back.pareto_alpha, cfg.pareto_alpha);
        assert_eq!(back.hotspot_every, cfg.hotspot_every);
        // static configs serialize without workload keys
        let text = ExperimentConfig::default().to_json().to_string();
        assert!(!text.contains("workload"), "unexpected workload key: {text}");
    }

    #[test]
    fn workload_rejections() {
        // unknown workload name
        assert!(ExperimentConfig::from_json_str(r#"{"workload": "batch"}"#).is_err());
        // churn knobs without a workload
        for knob in [
            r#"{"arrival_rate": 2.0}"#,
            r#"{"pareto_alpha": 3.0}"#,
            r#"{"hotspot_every": 8}"#,
        ] {
            assert!(
                ExperimentConfig::from_json_str(knob).is_err(),
                "accepted churn knob without workload: {knob}"
            );
        }
        // knob values outside the generator's domain
        assert!(ExperimentConfig::from_json_str(
            r#"{"workload": "service-traffic", "pareto_alpha": 1.0}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json_str(
            r#"{"workload": "service-traffic", "arrival_rate": -1.0}"#
        )
        .is_err());
    }

    #[test]
    fn topology_variants_parse() {
        for t in ["ring", "torus2d", "hypercube", "er:0.3"] {
            let cfg =
                ExperimentConfig::from_json_str(&format!(r#"{{"topology": "{t}", "n": 16}}"#))
                    .unwrap();
            assert_eq!(cfg.topology.name(), t);
        }
    }
}
