//! Ablation: protocol-family comparison at matched communication budget.
//!
//! DESIGN.md calls out two design choices the paper takes as given:
//! (1) deterministic BCM schedule (vs the random matching model §2.1
//! mentions) and (2) the matching model itself (vs diffusion, §1).
//! This bench runs all three on identical networks and load draws,
//! normalizing by rounds, and reports final discrepancy + movements.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{run, run_rmm, Diffusion, Schedule, StopRule};
use bcm_dlb::graph::Topology;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::stats::Welford;
use bcm_dlb::util::table::{f, Table};

fn main() {
    let quick = std::env::var("BCM_DLB_QUICK").map(|v| v == "1").unwrap_or(false);
    let reps = if quick { 5 } else { 20 };
    let sweeps = 12;
    let start = std::time::Instant::now();

    for topo in [Topology::RandomConnected, Topology::Torus2d, Topology::RandomRegular { d: 4 }] {
        let mut t = Table::new(
            &format!(
                "ablation {} n=32 L/n=50 ({} reps, {} sweeps-equivalent rounds)",
                topo.name(),
                reps,
                sweeps
            ),
            &["protocol", "final_disc", "disc_reduction", "movements", "moves/edge"],
        );
        let mut rows: Vec<(String, Welford, Welford, Welford, Welford)> = [
            "BCM + SortedGreedy",
            "BCM + Greedy (pooled)",
            "BCM + Greedy (incremental)",
            "RMM + SortedGreedy",
            "FOS diffusion",
        ]
        .iter()
        .map(|s| (s.to_string(), Welford::new(), Welford::new(), Welford::new(), Welford::new()))
        .collect();

        for rep in 0..reps {
            let mut rng = Pcg64::new(4000 + rep);
            let g = topo.build(32, &mut rng);
            let schedule = Schedule::from_graph(&g);
            let rounds = sweeps * schedule.period();
            let state0 = LoadState::init_uniform_counts(
                32,
                50,
                &WeightDistribution::paper_section6(),
                Mobility::Full,
                &mut rng,
            );
            let traces = vec![
                {
                    let mut s = state0.clone();
                    let mut r = Pcg64::new(1 + rep);
                    run(
                        &mut s,
                        &schedule,
                        PairAlgorithm::SortedGreedy(SortAlgo::Quick),
                        StopRule::sweeps(sweeps),
                        &mut r,
                    )
                },
                {
                    let mut s = state0.clone();
                    let mut r = Pcg64::new(2 + rep);
                    run(&mut s, &schedule, PairAlgorithm::Greedy, StopRule::sweeps(sweeps), &mut r)
                },
                {
                    let mut s = state0.clone();
                    let mut r = Pcg64::new(3 + rep);
                    run(
                        &mut s,
                        &schedule,
                        PairAlgorithm::GreedyIncremental,
                        StopRule::sweeps(sweeps),
                        &mut r,
                    )
                },
                {
                    let mut s = state0.clone();
                    let mut r = Pcg64::new(4 + rep);
                    run_rmm(
                        &mut s,
                        &g,
                        PairAlgorithm::SortedGreedy(SortAlgo::Quick),
                        rounds,
                        &mut r,
                    )
                },
                {
                    let mut s = state0.clone();
                    let mut r = Pcg64::new(5 + rep);
                    Diffusion::default().run(&mut s, &g, rounds, &mut r)
                },
            ];
            for ((_, fd, dr, mv, me), trace) in rows.iter_mut().zip(&traces) {
                fd.push(trace.final_discrepancy());
                dr.push(trace.discrepancy_reduction().min(1e9));
                mv.push(trace.total_movements() as f64);
                me.push(trace.movements_per_edge());
            }
        }
        for (name, fd, dr, mv, me) in rows {
            t.row(vec![
                name,
                f(fd.mean(), 2),
                format!("{}x", f(dr.mean(), 1)),
                f(mv.mean(), 0),
                f(me.mean(), 2),
            ]);
        }
        println!("{}", t.render());
        t.write_csv(std::path::Path::new(&format!(
            "results/ablation_{}.csv",
            topo.name().replace(':', "_")
        )))
        .ok();
    }
    eprintln!("ablation completed in {:.1}s", start.elapsed().as_secs_f64());
}
