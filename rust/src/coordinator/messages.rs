//! Message types of the sharded distributed BCM protocol.
//!
//! The communication structure mirrors the matching model the paper
//! assumes (§1, §2) at shard granularity: per round, only the edges that
//! cross a shard boundary exchange payloads (one [`ShardMsg::Offer`] from
//! the slave shard, one [`ShardMsg::Settle`] back from the master), while
//! intra-shard edges are solved with no messaging at all.  The leader is
//! pure control plane — it broadcasts one [`Ctl::RunBatch`] covering `B`
//! rounds per shard and collects one aggregated [`Report::Batch`] per
//! shard, so leader traffic is O(shards / B) per round and
//! worker-to-worker traffic is O(cross-shard edges) per round.
//!
//! Batching is what lets workers pipeline: within a batch no worker ever
//! waits on the leader, only on the peers its cut edges touch, so a
//! shard can run ahead into later rounds while a slower peer is still
//! collecting earlier ones.  Peer messages are therefore tagged with
//! their **round** in addition to their edge index; a receiver stashes
//! messages that arrive early.
//!
//! Since the multi-tenant service, every data-plane message also carries
//! a **job id**: a shard pool runs several independent balancing jobs on
//! the same worker set, and `(round, edge)` keys repeat across jobs.  Job
//! `0` is the classic single-job id installed by `Cluster::spawn*` and
//! the TCP `Init` handshake; [`Ctl::OpenJob`]/[`Ctl::CloseJob`] add and
//! retire further jobs at runtime without restarting workers.
//!
//! These types are transport-agnostic: they cross in-process channels on
//! the [`local`](super::transport::local) backend and travel as
//! length-prefixed binary frames ([`codec`](super::transport::codec)) on
//! the [`tcp`](super::transport::tcp) backend.  The full
//! message-by-message spec — including the normative on-the-wire frame
//! format — lives in `DESIGN.md` §"Cluster wire protocol".

use super::shard::RoundPlan;
use crate::load::Load;
use crate::workload::service_traffic::ChurnOp;
use std::sync::Arc;

/// Leader -> worker control messages.
#[derive(Debug, PartialEq)]
pub enum Ctl {
    /// Install a new job on the worker: the shard's node slice plus the
    /// pair algorithm to run.  Workers spawned through `Cluster` have
    /// job `0` pre-installed; a shard pool opens every job this way.
    OpenJob {
        /// Job the slice belongs to.
        job: u32,
        /// Global index of the first node in `nodes`.
        lo: usize,
        /// Pair algorithm name (`PairAlgorithm::parse` format).
        algo: String,
        /// Per-node load lists of the shard's slice, in node order.
        nodes: Vec<Vec<Load>>,
    },
    /// Retire a job: the worker replies with that job's
    /// [`Report::Final`] and frees its state; other jobs keep running.
    CloseJob {
        /// Job to retire.
        job: u32,
    },
    /// Execute rounds `start_round .. start_round + rounds` of one job
    /// as one pipelined batch, reporting back a single
    /// [`Report::Batch`].
    ///
    /// `seed` keys the counter-based per-edge RNG streams
    /// (`Pcg64::for_edge(seed, round, edge)`), replacing the leader-drawn
    /// coin flips of the historical cluster — the source of the sharded
    /// runtime's bit-identity with `bcm::Sequential` at every
    /// (shards, batch) combination: no RNG state ever crosses a message.
    RunBatch {
        /// Job the batch belongs to.
        job: u32,
        /// Global index of the batch's first round.
        start_round: usize,
        /// Number of rounds in the batch (`B >= 1`).
        rounds: usize,
        /// Run seed; every edge of round `r` draws from
        /// `Pcg64::for_edge(seed, r, edge)`.
        seed: u64,
        /// Per-color plan table (one entry per schedule color, shared
        /// zero-copy across shards and batches); round `r` executes
        /// `plans[r % plans.len()]`.
        plans: Arc<Vec<Arc<RoundPlan>>>,
        /// When set, the worker follows its [`Report::Batch`] with a
        /// [`Report::Checkpoint`] snapshotting the job's slice as it
        /// stands after the batch's last round.  FIFO report links make
        /// the pair arrive in order, so the leader always knows which
        /// round a checkpoint describes.
        checkpoint: bool,
    },
    /// Report one job's per-node weights to the leader.
    PollWeights {
        /// Job whose weights to report.
        job: u32,
    },
    /// Apply a churn-op slice to one job's node lists **before** the
    /// next balancing round (`workload::service_traffic`).  The leader
    /// slices the round's global op stream per shard and sends only each
    /// shard's ops, on the same FIFO control link as the following
    /// [`Ctl::RunBatch`] — ordering, not acknowledgement, is what makes
    /// the round see the post-churn state, so no reply is sent.  Op
    /// application is deterministic (`apply_ops_nodes` mirrors the
    /// engine-side `apply_ops` bit-for-bit), preserving the cluster's
    /// bit-identity with `bcm::Sequential` under churn.
    ApplyChurn {
        /// Job whose node lists to mutate.
        job: u32,
        /// This shard's slice of the round's op stream, in stream order.
        ops: Vec<ChurnOp>,
    },
    /// Unconditionally retire a job with **no reply**: purge its state
    /// and stash, clear any failure already recorded against it, keep
    /// serving other jobs.  Idempotent — aborting an unknown or already
    /// retired job is a no-op.  This is the recovery primitive: the
    /// leader aborts the failed epoch everywhere before replaying it
    /// from a checkpoint under a fresh job id (`DESIGN.md` §8).
    AbortJob {
        /// Job to retire.
        job: u32,
    },
    /// Re-establish the peer link to `shard` at `addr`: drop the old
    /// (dead) connection and dial the rejoined worker's fresh peer
    /// listener.  Sent by the leader to every survivor after a rejoin;
    /// survivor-to-survivor links are untouched (`DESIGN.md` §8).
    Remesh {
        /// Shard whose peer link to replace.
        shard: usize,
        /// The rejoined worker's new peer listener address.
        addr: String,
    },
    /// Terminate and return every open job's final load lists.
    Shutdown,
}

/// Worker -> worker payloads, tagged with the job and round they belong
/// to and the edge's index within that round's matching (which also keys
/// the edge's RNG stream).
///
/// The round tag is what makes pipelining safe: edge indices repeat
/// across rounds, and within a batch a fast shard may send round `r+1`
/// traffic while a peer is still collecting round `r` — the receiver
/// stashes any message whose round is ahead of its own.  The job tag
/// extends the same argument across tenants: `(round, edge)` keys repeat
/// across concurrent jobs, and a peer may not even have processed a
/// job's `OpenJob` yet when its first offer arrives.
#[derive(Debug, PartialEq)]
pub enum ShardMsg {
    /// Slave -> master: `v`'s mobile loads (in node order) and its pinned
    /// weight sum.
    Offer {
        /// Job the offer belongs to.
        job: u32,
        /// Global round the offer belongs to.
        round: usize,
        /// Edge index within the round's matching.
        edge: usize,
        /// `v`'s mobile loads, in node order.
        loads: Vec<Load>,
        /// Sum of `v`'s pinned load weights (stays on `v`).
        pinned: f64,
    },
    /// Master -> slave: `v`'s new mobile loads.
    Settle {
        /// Job the settle belongs to.
        job: u32,
        /// Global round the settle belongs to.
        round: usize,
        /// Edge index within the round's matching.
        edge: usize,
        /// The mobile loads assigned back to `v`.
        loads: Vec<Load>,
    },
}

/// Per-round metrics inside a [`Report::Batch`]: the shard's movement
/// count for the edges it mastered, its node-weight extremes after the
/// round (the leader folds these into the global discrepancy — exact,
/// because f64 min/max are associative), and the peer messages it sent.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundReport {
    /// Global round index the entry describes.
    pub round: usize,
    /// Loads moved by the edges this shard mastered (local + master).
    pub movements: usize,
    /// Minimum node weight on this shard after the round.
    pub min_weight: f64,
    /// Maximum node weight on this shard after the round.
    pub max_weight: f64,
    /// Peer messages (offers + settles) this shard sent for the round.
    pub peer_msgs: usize,
}

/// Worker -> leader reports.
#[derive(Debug, PartialEq)]
pub enum Report {
    /// A whole batch finished on this shard: one [`RoundReport`] per
    /// round, in ascending round order.  Coalescing the per-round
    /// metrics into one message is the reply half of the
    /// [`Ctl::RunBatch`] amortization.
    Batch {
        /// Job the batch belongs to.
        job: u32,
        /// Reporting shard.
        shard: usize,
        /// Per-round metrics, one entry per round of the batch.
        rounds: Vec<RoundReport>,
    },
    /// Per-node weights of one job's shard slice (in response to
    /// [`Ctl::PollWeights`]).
    Weights {
        /// Job the weights belong to.
        job: u32,
        /// Reporting shard.
        shard: usize,
        /// Weight of each node the shard owns, in node order.
        weights: Vec<f64>,
    },
    /// Snapshot of one job's shard slice after a batch whose
    /// [`Ctl::RunBatch`] had `checkpoint` set.  Sent immediately after
    /// the batch's [`Report::Batch`] on the same FIFO link; the leader
    /// assembles the per-shard slices of a round into a full
    /// recovery image (`DESIGN.md` §8).  Batch boundaries are globally
    /// consistent cut points — every peer exchange of the batch's
    /// rounds has drained before the worker reports — so the assembled
    /// image equals `bcm::Sequential`'s state after the same round.
    Checkpoint {
        /// Job the snapshot belongs to.
        job: u32,
        /// Reporting shard.
        shard: usize,
        /// Global index of the last executed round the snapshot
        /// reflects (the batch's `start_round + rounds - 1`).
        round: usize,
        /// Per-node load lists of the shard's slice, in node order.
        nodes: Vec<Vec<Load>>,
    },
    /// Final load lists of one job's shard slice (in response to
    /// [`Ctl::CloseJob`] or, for every open job, [`Ctl::Shutdown`]).
    Final {
        /// Job the slice belongs to.
        job: u32,
        /// Reporting shard.
        shard: usize,
        /// Per-node load lists, in node order.
        nodes: Vec<Vec<Load>>,
    },
    /// Failure on the worker (protocol violation, dead peer, or a caught
    /// panic); the leader surfaces it as a `util::error` instead of
    /// wedging.  A mid-batch failure names the round it died in.
    ///
    /// `job: Some(j)` scopes the failure to job `j` — the worker retires
    /// that job and keeps serving the others.  `job: None` is
    /// worker-fatal (or synthesized by the leader transport for a lost
    /// connection) and poisons everything the worker was running.
    Error {
        /// Failing job, when the failure is scoped to one job.
        job: Option<u32>,
        /// Failing shard.
        shard: usize,
        /// Round being executed when the failure hit, when attributable.
        round: Option<usize>,
        /// Human-readable failure description.
        message: String,
    },
}
