//! The AOT artifact manifest (`artifacts/manifest.json`).
//!
//! Written once by `python/compile/aot.py`; describes every HLO-text
//! artifact: entry point, file, input/output shapes and dtypes.  The
//! runtime uses it to pick the smallest shape bucket that fits a batch.

use crate::anyhow;
use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// Logical entry point (e.g. "balance_two_bin").
    pub entry: String,
    /// File name relative to the artifacts dir.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// For two-bin entries: (B, M) of the weights input.
    pub fn batch_shape(&self) -> Option<(usize, usize)> {
        let s = &self.inputs.first()?.shape;
        if s.len() == 2 {
            Some((s[0], s[1]))
        } else {
            None
        }
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {}", mpath.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        if root.get("format").as_str() != Some("hlo-text") {
            bail!("manifest format must be 'hlo-text'");
        }
        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: missing artifacts[]"))?
        {
            artifacts.push(ArtifactSpec {
                name: req_str(a, "name")?,
                entry: req_str(a, "entry")?,
                file: req_str(a, "file")?,
                inputs: tensors(a.get("inputs"))?,
                outputs: tensors(a.get("outputs"))?,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifacts for a given entry point.
    pub fn entries(&self, entry: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.entry == entry).collect()
    }

    /// Smallest (by B*M) artifact of `entry` with B >= b and M >= m.
    pub fn pick_bucket(&self, entry: &str, b: usize, m: usize) -> Option<&ArtifactSpec> {
        self.entries(entry)
            .into_iter()
            .filter_map(|a| a.batch_shape().map(|(ab, am)| (a, ab, am)))
            .filter(|&(_, ab, am)| ab >= b && am >= m)
            .min_by_key(|&(_, ab, am)| ab * am)
            .map(|(a, _, _)| a)
    }

    /// Bucket that minimizes launches for a `batch`-problem round (each
    /// problem at most `m` balls), breaking ties by padded area.  Launch
    /// dispatch costs ~ms on the CPU PJRT client, so fewer launches beats
    /// tighter padding (§Perf experiment C).
    pub fn pick_bucket_for_batch(
        &self,
        entry: &str,
        batch: usize,
        m: usize,
    ) -> Option<&ArtifactSpec> {
        self.entries(entry)
            .into_iter()
            .filter_map(|a| a.batch_shape().map(|(ab, am)| (a, ab, am)))
            .filter(|&(_, _, am)| am >= m)
            .min_by_key(|&(_, ab, am)| (batch.max(1).div_ceil(ab), ab * am))
            .map(|(a, _, _)| a)
    }

    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow!("manifest: missing string field '{key}'"))
}

fn tensors(v: &Json) -> Result<Vec<TensorSpec>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("manifest: expected tensor array"))?;
    arr.iter()
        .map(|t| {
            Ok(TensorSpec {
                name: req_str(t, "name")?,
                shape: t
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow!("tensor missing shape"))?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()?,
                dtype: req_str(t, "dtype")?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "version": 1,
      "artifacts": [
        {"name": "balance_two_bin_b8_m64", "entry": "balance_two_bin",
         "file": "balance_two_bin_b8_m64.hlo.txt",
         "inputs": [{"name":"weights","shape":[8,64],"dtype":"f32"},
                    {"name":"base","shape":[8,2],"dtype":"f32"}],
         "outputs": [{"name":"sorted_w","shape":[8,64],"dtype":"f32"},
                     {"name":"perm","shape":[8,64],"dtype":"i32"},
                     {"name":"assign","shape":[8,64],"dtype":"f32"},
                     {"name":"sums","shape":[8,2],"dtype":"f32"}]},
        {"name": "balance_two_bin_b64_m256", "entry": "balance_two_bin",
         "file": "balance_two_bin_b64_m256.hlo.txt",
         "inputs": [{"name":"weights","shape":[64,256],"dtype":"f32"},
                    {"name":"base","shape":[64,2],"dtype":"f32"}],
         "outputs": []}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.by_name("balance_two_bin_b8_m64").unwrap();
        assert_eq!(a.entry, "balance_two_bin");
        assert_eq!(a.inputs[0].shape, vec![8, 64]);
        assert_eq!(a.outputs[1].dtype, "i32");
        assert_eq!(a.batch_shape(), Some((8, 64)));
    }

    #[test]
    fn pick_bucket_smallest_fit() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let a = m.pick_bucket("balance_two_bin", 4, 32).unwrap();
        assert_eq!(a.name, "balance_two_bin_b8_m64");
        let b = m.pick_bucket("balance_two_bin", 16, 64).unwrap();
        assert_eq!(b.name, "balance_two_bin_b64_m256");
        assert!(m.pick_bucket("balance_two_bin", 128, 64).is_none());
        assert!(m.pick_bucket("nope", 1, 1).is_none());
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(Path::new("/tmp"), r#"{"format":"proto"}"#).is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "not json").is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            assert!(m.pick_bucket("balance_two_bin", 8, 64).is_some());
            for a in &m.artifacts {
                assert!(m.path_of(a).exists(), "{} missing", a.file);
            }
        }
    }
}
