//! Dynamic-workload throughput: churning rounds/s of the
//! `service-traffic` generator driven through every executor — the
//! sequential engine, the parallel engine, and the sharded cluster.
//!
//! Every executor's trace and final state are checked bit-identical
//! against `bcm::Sequential` before its time is reported, so this bench
//! doubles as the churn-determinism smoke test at bench scale: churn
//! application (arena inserts, modular departures, drift rescales) must
//! not cost determinism at any thread or shard count.
//!
//! `cargo bench --bench service_traffic` runs the n=1024 scenario;
//! `-- --smoke` (or `BCM_DLB_SMOKE=1` / `BCM_DLB_QUICK=1`) derates to
//! n=128, 1 sweep for CI.  Smoke runs enforce the
//! `[service_traffic.smoke] min_rounds_per_s` floor from
//! `bench_floor.toml`; `-- --no-floor` skips the gate, and hosts with
//! fewer cores than the recorded `pinned_cores` skip it automatically
//! with a notice.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{Parallel, RunTrace, Schedule, Sequential};
use bcm_dlb::graph::Topology;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::table::{f, Table};
use bcm_dlb::workload::{
    run_dynamic_cluster, run_dynamic_engine, sustained_stats, TrafficConfig,
};
use std::path::Path;

const ALGO: PairAlgorithm = PairAlgorithm::SortedGreedy(SortAlgo::Quick);
const SEED: u64 = 2013;

fn read_floor(path: &Path, section: &str, key: &str) -> Option<f64> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut in_section = false;
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            in_section = name.trim() == section;
        } else if in_section {
            if let Some((k, v)) = line.split_once('=') {
                if k.trim() == key {
                    return v.trim().parse().ok();
                }
            }
        }
    }
    None
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1").unwrap_or(false)
}

/// Scenario seeded exactly like `bcm-dlb run --workload service-traffic`.
fn scenario(n: usize, sweeps: usize) -> (Schedule, LoadState, usize) {
    let mut rng = Pcg64::new(SEED);
    let g = Topology::Torus2d.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        n,
        10,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let rounds = sweeps * schedule.period();
    (schedule, state, rounds)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke")
        || env_flag("BCM_DLB_SMOKE")
        || env_flag("BCM_DLB_QUICK");
    let (n, sweeps) = if smoke { (128, 1) } else { (1024, 2) };
    let cfg = TrafficConfig::default();
    let (schedule, state0, rounds) = scenario(n, sweeps);
    eprintln!(
        "service_traffic: torus2d n={n}, {rounds} churning rounds, \
         arrival_rate={}, pareto_alpha={}{}",
        cfg.arrival_rate,
        cfg.pareto_alpha,
        if smoke { " (smoke)" } else { "" }
    );

    // the sequential reference first: its trace/state gate the others
    let mut seq_state = state0.clone();
    let start = std::time::Instant::now();
    let seq_trace = run_dynamic_engine(
        &Sequential,
        &mut seq_state,
        &schedule,
        ALGO,
        &cfg,
        rounds,
        SEED,
    );
    let seq_secs = start.elapsed().as_secs_f64();

    let mut t = Table::new(
        "service-traffic churning throughput (every executor verified vs Sequential)",
        &["executor", "rounds", "secs", "rounds/s", "sustained_mean"],
    );
    let mut best_rps: f64 = 0.0;
    let mut failed = false;
    let mut record = |name: &str, trace: &RunTrace, secs: f64| {
        let rps = trace.rounds.len() as f64 / secs.max(1e-12);
        best_rps = best_rps.max(rps);
        let s = sustained_stats(trace, rounds / 2);
        t.row(vec![
            name.to_string(),
            trace.rounds.len().to_string(),
            f(secs, 3),
            f(rps, 0),
            f(s.mean, 4),
        ]);
    };
    record("sequential", &seq_trace, seq_secs);

    for threads in [2usize, 0] {
        let name = if threads == 0 {
            "parallel/auto".to_string()
        } else {
            format!("parallel/{threads}")
        };
        let mut state = state0.clone();
        let start = std::time::Instant::now();
        let trace = run_dynamic_engine(
            &Parallel::new(threads),
            &mut state,
            &schedule,
            ALGO,
            &cfg,
            rounds,
            SEED,
        );
        let secs = start.elapsed().as_secs_f64();
        if trace != seq_trace || state != seq_state {
            eprintln!("service_traffic: {name} diverged from Sequential under churn");
            failed = true;
            continue;
        }
        record(&name, &trace, secs);
    }

    for shards in [2usize, 0] {
        let name = if shards == 0 {
            "cluster/auto".to_string()
        } else {
            format!("cluster/{shards}")
        };
        let start = std::time::Instant::now();
        match run_dynamic_cluster(state0.clone(), &schedule, ALGO, &cfg, rounds, SEED, shards)
        {
            Ok((trace, fin)) => {
                let secs = start.elapsed().as_secs_f64();
                if trace != seq_trace || fin != seq_state {
                    eprintln!(
                        "service_traffic: {name} diverged from Sequential under churn"
                    );
                    failed = true;
                    continue;
                }
                record(&name, &trace, secs);
            }
            Err(e) => {
                eprintln!("service_traffic: {name} failed: {e}");
                failed = true;
            }
        }
    }

    println!("{}", t.render());
    t.write_csv(Path::new("results/service_traffic_bench.csv")).ok();

    if smoke && !args.iter().any(|a| a == "--no-floor") {
        let floor_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_floor.toml");
        // the floor was pinned on a `pinned_cores` container; a smaller
        // host cannot hold it — skip with a notice instead of failing
        let host_cores = std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(1);
        let pinned = read_floor(&floor_path, "service_traffic.smoke", "pinned_cores");
        let undersized = match pinned {
            Some(p) => (host_cores as f64) < p,
            None => false,
        };
        if undersized {
            eprintln!(
                "service_traffic: perf floor SKIPPED — this host has {host_cores} \
                 core(s), fewer than the bench_floor.toml pinned_cores the floor was \
                 pinned on"
            );
        } else {
            match read_floor(&floor_path, "service_traffic.smoke", "min_rounds_per_s") {
                Some(floor) if best_rps < floor => {
                    eprintln!(
                        "REGRESSION: best churning throughput {} rounds/s is below the \
                         bench_floor.toml floor of {} rounds/s",
                        f(best_rps, 0),
                        f(floor, 0)
                    );
                    failed = true;
                }
                Some(floor) => {
                    eprintln!(
                        "perf floor ok: {} rounds/s >= {} rounds/s floor",
                        f(best_rps, 0),
                        f(floor, 0)
                    );
                }
                None => {
                    eprintln!(
                        "REGRESSION GATE BROKEN: no parsable [service_traffic.smoke] \
                         min_rounds_per_s in {} (use --no-floor to bypass deliberately)",
                        floor_path.display()
                    );
                    failed = true;
                }
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
}
