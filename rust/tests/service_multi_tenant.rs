//! Multi-tenant determinism: several independent jobs sharing one
//! [`ShardPool`] must each be bit-identical to a solo `bcm::Sequential`
//! run, one tenant's failure must not perturb the others, and the
//! `serve` loopback path must stream and verify end to end.

use bcm_dlb::balancer::PairAlgorithm;
use bcm_dlb::bcm::{Engine, RoundStats, RunTrace, Schedule, Sequential, StopRule};
use bcm_dlb::coordinator::{JobEvent, JobSpec, ShardPool};
use bcm_dlb::graph::Topology;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::service::{submit, ServeOptions, Server};
use bcm_dlb::util::json::Json;
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::workload::{run_dynamic_engine, TrafficConfig};
use std::collections::BTreeMap;
use std::time::Duration;

/// A tenant's spec plus everything needed to re-run it solo.
struct Tenant {
    spec: JobSpec,
    state: LoadState,
    schedule: Schedule,
    algo: PairAlgorithm,
    sweeps: usize,
    seed: u64,
}

/// Build a tenant exactly like `bcm-dlb run`'s first repetition.
fn tenant(topo: &str, n: usize, algo: &str, sweeps: usize, seed: u64, batch: usize) -> Tenant {
    let topo = Topology::parse(topo).expect("test topology");
    let algo = PairAlgorithm::parse(algo).expect("test algorithm");
    let mut rng = Pcg64::new(seed);
    let g = topo.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        n,
        8,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    Tenant {
        spec: JobSpec {
            state: state.clone(),
            schedule: schedule.clone(),
            algo,
            sweeps,
            seed,
            batch,
            checkpoint_every: 0,
            churn: None,
        },
        state,
        schedule,
        algo,
        sweeps,
        seed,
    }
}

fn solo_reference(t: &Tenant) -> (RunTrace, LoadState) {
    let mut state = t.state.clone();
    let trace = Sequential.run(
        &mut state,
        &t.schedule,
        t.algo,
        StopRule::sweeps(t.sweeps),
        t.seed,
    );
    (trace, state)
}

/// The solo reference of a *churning* tenant: `Sequential` driven
/// through the same per-round churn stream the pool ships its shards.
fn churn_solo(t: &Tenant, cfg: &TrafficConfig) -> (RunTrace, LoadState) {
    let mut state = t.state.clone();
    let rounds = t.sweeps * t.schedule.period();
    let trace =
        run_dynamic_engine(&Sequential, &mut state, &t.schedule, t.algo, cfg, rounds, t.seed);
    (trace, state)
}

#[derive(Default)]
struct Outcome {
    initial: Option<f64>,
    rounds: Vec<RoundStats>,
    finished: Option<(RunTrace, LoadState)>,
    failed: Option<String>,
}

impl Outcome {
    fn terminal(&self) -> bool {
        self.finished.is_some() || self.failed.is_some()
    }
}

/// Drive the pool until every job in `ids` reaches a terminal event.
fn drive(pool: &mut ShardPool, ids: &[u32]) -> BTreeMap<u32, Outcome> {
    let mut out: BTreeMap<u32, Outcome> = ids.iter().map(|&id| (id, Outcome::default())).collect();
    while out.values().any(|o| !o.terminal()) {
        let events = pool.step(Duration::from_millis(50)).expect("pool healthy");
        for ev in events {
            match ev {
                JobEvent::Started {
                    job,
                    initial_discrepancy,
                } => out.get_mut(&job).unwrap().initial = Some(initial_discrepancy),
                JobEvent::Rounds { job, stats } => {
                    out.get_mut(&job).unwrap().rounds.extend(stats)
                }
                JobEvent::Finished { job, trace, state } => {
                    out.get_mut(&job).unwrap().finished = Some((trace, state))
                }
                JobEvent::Failed { job, error } => {
                    out.get_mut(&job).unwrap().failed = Some(error)
                }
                JobEvent::Recovering { .. } => {}
            }
        }
    }
    out
}

#[test]
fn concurrent_jobs_are_bit_identical_to_solo_sequential() {
    // three tenants with different topologies, algorithms, seeds, and
    // batch sizes, interleaved on one three-worker pool
    let tenants = vec![
        tenant("ring", 24, "greedy", 3, 11, 1),
        tenant("torus2d", 16, "sorted:quick", 2, 7, 0),
        tenant("complete", 12, "random", 2, 42, 2),
    ];
    let refs: Vec<(RunTrace, LoadState)> = tenants.iter().map(solo_reference).collect();

    let mut pool = ShardPool::spawn(3);
    let mut ids = Vec::new();
    for t in tenants {
        ids.push(pool.open_job(t.spec).expect("job opens"));
    }
    assert_eq!(pool.jobs_active(), ids.len());
    let out = drive(&mut pool, &ids);

    for (id, (seq_trace, seq_state)) in ids.iter().zip(&refs) {
        let o = &out[id];
        assert_eq!(o.failed, None, "job {id} failed");
        let (trace, state) = o.finished.as_ref().expect("finished");
        assert_eq!(trace, seq_trace, "job {id} trace diverged from Sequential");
        assert_eq!(state, seq_state, "job {id} final state diverged");
        // the streamed Rounds events are the trace, delivered incrementally
        assert_eq!(o.rounds, trace.rounds, "job {id} stream != trace");
        assert_eq!(o.initial, Some(trace.initial_discrepancy));
    }
    pool.shutdown().expect("clean shutdown");
}

#[test]
fn one_tenant_failing_mid_batch_does_not_poison_the_others() {
    let survivor = tenant("ring", 24, "sorted:quick", 3, 5, 1);
    let doomed = tenant("torus2d", 16, "greedy", 3, 6, 1);
    let survivor_ref = solo_reference(&survivor);

    // ids are assigned from 1 in open order: survivor=1, doomed=2.
    // Inject a panic on shard 0 at (job 2, round 1); surviving shards of
    // job 2 notice via the shortened peer wait and self-retire.
    let mut pool = ShardPool::spawn_tuned(2, Some((0, 2, 1)), Some(Duration::from_millis(250)));
    let id_s = pool.open_job(survivor.spec).expect("survivor opens");
    let id_d = pool.open_job(doomed.spec).expect("doomed opens");
    assert_eq!((id_s, id_d), (1, 2));

    let out = drive(&mut pool, &[id_s, id_d]);

    let err = out[&id_d].failed.as_ref().expect("doomed job fails");
    assert!(
        err.contains("injected fault") || err.contains("timed out waiting for peer"),
        "unexpected failure: {err}"
    );
    assert!(out[&id_d].finished.is_none());

    let o = &out[&id_s];
    assert_eq!(o.failed, None, "survivor poisoned: {:?}", o.failed);
    let (trace, state) = o.finished.as_ref().expect("survivor finishes");
    assert_eq!(trace, &survivor_ref.0, "survivor trace diverged");
    assert_eq!(state, &survivor_ref.1, "survivor state diverged");

    // the pool stays serviceable for new tenants after the failure
    let again = tenant("ring", 24, "sorted:quick", 3, 5, 1);
    let id3 = pool.open_job(again.spec).expect("pool accepts new jobs");
    let out = drive(&mut pool, &[id3]);
    let (trace, _) = out[&id3].finished.as_ref().expect("new job finishes");
    assert_eq!(trace, &survivor_ref.0);
    pool.shutdown().expect("clean shutdown");
}

#[test]
fn churning_and_static_tenants_share_a_pool() {
    // soak: one tenant under live service-traffic churn, one classic
    // static tenant, interleaved on the same three-worker pool
    let cfg = TrafficConfig::default();
    let mut churned = tenant("torus2d", 16, "sorted:quick", 3, 21, 0);
    churned.spec.churn = Some(cfg.clone());
    let static_t = tenant("ring", 24, "greedy", 3, 22, 2);
    let churn_ref = churn_solo(&churned, &cfg);
    let static_ref = solo_reference(&static_t);

    let mut pool = ShardPool::spawn(3);
    let id_c = pool.open_job(churned.spec).expect("churning job opens");
    let id_s = pool.open_job(static_t.spec).expect("static job opens");
    let out = drive(&mut pool, &[id_c, id_s]);

    // the churning tenant is bit-identical to its solo Sequential
    // dynamic run — trace, streamed rounds, and reassembled final state
    // (including the next_id high-water mark of departed arrivals)
    let o = &out[&id_c];
    assert_eq!(o.failed, None, "churning job failed");
    let (trace, state) = o.finished.as_ref().expect("churning job finishes");
    assert_eq!(trace, &churn_ref.0, "churning trace diverged from Sequential");
    assert_eq!(state, &churn_ref.1, "churning final state diverged");
    assert_eq!(o.rounds, trace.rounds, "churn stream != trace");
    assert_eq!(o.initial, Some(trace.initial_discrepancy));

    // the static neighbor is untouched by the churn traffic: identical
    // to Sequential, and byte-identical to a pool run with no neighbor
    let o = &out[&id_s];
    assert_eq!(o.failed, None, "static job failed");
    let (trace, state) = o.finished.as_ref().expect("static job finishes");
    assert_eq!(trace, &static_ref.0, "static trace diverged from Sequential");
    assert_eq!(state, &static_ref.1, "static final state diverged");
    pool.shutdown().expect("clean shutdown");

    let mut solo_pool = ShardPool::spawn(3);
    let alone = tenant("ring", 24, "greedy", 3, 22, 2);
    let id = solo_pool.open_job(alone.spec).expect("solo job opens");
    let solo_out = drive(&mut solo_pool, &[id]);
    let (solo_trace, solo_state) = solo_out[&id].finished.as_ref().expect("solo finishes");
    assert_eq!(solo_trace, trace, "churning neighbor changed the static trace");
    assert_eq!(solo_state, state, "churning neighbor changed the static state");
    solo_pool.shutdown().expect("clean shutdown");
}

#[test]
fn mid_churn_fault_poisons_only_its_tenant() {
    // ids are assigned from 1 in open order: churning=1, static=2.
    // Inject a panic on shard 0 at (job 1, round 2) — after churn ops
    // for rounds 0..=2 have already mutated the shard's lists.
    let cfg = TrafficConfig::default();
    let mut churned = tenant("torus2d", 16, "greedy", 3, 31, 1);
    churned.spec.churn = Some(cfg);
    let static_t = tenant("ring", 24, "sorted:quick", 3, 32, 1);
    let static_ref = solo_reference(&static_t);

    let mut pool = ShardPool::spawn_tuned(2, Some((0, 1, 2)), Some(Duration::from_millis(250)));
    let id_c = pool.open_job(churned.spec).expect("churning job opens");
    let id_s = pool.open_job(static_t.spec).expect("static job opens");
    assert_eq!((id_c, id_s), (1, 2));
    let out = drive(&mut pool, &[id_c, id_s]);

    let err = out[&id_c].failed.as_ref().expect("churning job fails");
    assert!(
        err.contains("injected fault") || err.contains("timed out waiting for peer"),
        "unexpected failure: {err}"
    );
    assert!(out[&id_c].finished.is_none());

    let o = &out[&id_s];
    assert_eq!(o.failed, None, "static tenant poisoned: {:?}", o.failed);
    let (trace, state) = o.finished.as_ref().expect("static tenant finishes");
    assert_eq!(trace, &static_ref.0, "static trace diverged after neighbor fault");
    assert_eq!(state, &static_ref.1, "static state diverged after neighbor fault");
    pool.shutdown().expect("clean shutdown");
}

#[test]
fn serve_loopback_streams_verified_jobs_concurrently() {
    let mut server = Server::bind(ServeOptions {
        listen: "127.0.0.1:0".to_string(),
        max_jobs: 2,
        shards: 2,
        max_conns: 8,
    })
    .expect("bind ephemeral port");
    let addr = server.local_addr().to_string();
    let server = std::thread::spawn(move || server.run());

    // three concurrent clients — two static, one under service-traffic
    // churn — each asking the service to verify the streamed run
    // against Sequential (the churning one against its dynamic twin)
    let lines = [
        r#"{"topology":"ring","n":16,"loads_per_node":8,"sweeps":2,"seed":3,"verify":true}"#,
        r#"{"topology":"ring","n":16,"loads_per_node":8,"sweeps":2,"seed":9,"verify":true}"#,
        r#"{"topology":"ring","n":16,"loads_per_node":8,"sweeps":2,"seed":5,"workload":"service-traffic","arrival_rate":1.5,"verify":true}"#,
    ];
    let clients: Vec<_> = lines
        .into_iter()
        .map(|line| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut out = Vec::new();
                let ok = submit(&addr, line, &mut out).expect("submit transport ok");
                (ok, String::from_utf8(out).unwrap())
            })
        })
        .collect();

    for c in clients {
        let (ok, log) = c.join().unwrap();
        assert!(ok, "job errored:\n{log}");
        let events: Vec<Json> = log.lines().map(|l| Json::parse(l).expect("valid json")).collect();
        assert_eq!(events[0].get("event").as_str(), Some("accepted"));
        assert_eq!(events[1].get("event").as_str(), Some("start"));
        let rounds = events
            .iter()
            .filter(|e| e.get("event").as_str() == Some("round"))
            .count();
        let done = events.last().unwrap();
        assert_eq!(done.get("event").as_str(), Some("done"));
        assert_eq!(done.get("verified").as_bool(), Some(true));
        assert_eq!(done.get("rounds").as_usize(), Some(rounds));
        assert!(rounds > 0, "no per-round lines streamed");
    }

    let mut out = Vec::new();
    assert!(submit(&addr, r#"{"cmd":"shutdown"}"#, &mut out).expect("shutdown submit"));
    assert!(String::from_utf8(out).unwrap().contains("\"event\":\"shutdown\""));
    server.join().unwrap().expect("server exits cleanly");
}

#[test]
fn serve_loopback_streams_stats_before_done() {
    let mut server = Server::bind(ServeOptions {
        listen: "127.0.0.1:0".to_string(),
        max_jobs: 2,
        shards: 2,
        max_conns: 8,
    })
    .expect("bind ephemeral port");
    let addr = server.local_addr().to_string();
    let server = std::thread::spawn(move || server.run());

    // "stats": true (bcm-dlb submit --stats) buys exactly one extra
    // event line, immediately before the terminal done
    let line = r#"{"topology":"ring","n":16,"loads_per_node":8,"sweeps":2,"seed":4,"stats":true}"#;
    let mut out = Vec::new();
    let ok = submit(&addr, line, &mut out).expect("submit transport ok");
    let log = String::from_utf8(out).unwrap();
    assert!(ok, "job errored:\n{log}");
    let events: Vec<Json> = log.lines().map(|l| Json::parse(l).expect("valid json")).collect();
    let stats: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("event").as_str() == Some("stats"))
        .collect();
    assert_eq!(stats.len(), 1, "expected exactly one stats line:\n{log}");
    let s = stats[0];
    // this job was alone on the pool, so zero *other* jobs were active
    // when it finished, and its throughput is positive and finite
    assert_eq!(s.get("jobs_active").as_usize(), Some(0));
    let rps = s.get("rounds_per_s").as_f64().expect("rounds_per_s present");
    assert!(rps > 0.0 && rps.is_finite(), "bad rounds_per_s: {rps}");
    // stats is the penultimate line; done stays terminal
    assert_eq!(
        events[events.len() - 2].get("event").as_str(),
        Some("stats")
    );
    assert_eq!(events.last().unwrap().get("event").as_str(), Some("done"));

    // a spec without the flag gets no stats line
    let line = r#"{"topology":"ring","n":16,"loads_per_node":8,"sweeps":2,"seed":4}"#;
    let mut out = Vec::new();
    assert!(submit(&addr, line, &mut out).expect("submit transport ok"));
    let log = String::from_utf8(out).unwrap();
    assert!(!log.contains("\"event\":\"stats\""), "unexpected stats line:\n{log}");

    let mut out = Vec::new();
    assert!(submit(&addr, r#"{"cmd":"shutdown"}"#, &mut out).expect("shutdown submit"));
    server.join().unwrap().expect("server exits cleanly");
}
