//! Sorting substrate for SortedGreedy (paper §4.1).
//!
//! The paper uses MATLAB's intrinsic quicksort and discusses
//! distribution-based O(m) sorts (bucketsort, Proxmap-sort, flashsort) for
//! uniform weights, falling back to comparison sorts (quicksort,
//! mergesort) for arbitrary distributions.  We implement all of them so
//! the timing table (§11.3) and the sorting-overhead claim can be
//! reproduced with each variant.
//!
//! All sorts order *descending* by key (the SortedGreedy precondition).

/// Anything sortable by a non-negative f64 key.
pub trait Keyed {
    fn key(&self) -> f64;
}

impl Keyed for f64 {
    #[inline]
    fn key(&self) -> f64 {
        *self
    }
}

impl Keyed for crate::load::Load {
    #[inline]
    fn key(&self) -> f64 {
        self.weight
    }
}

/// Which sort SortedGreedy uses (configurable; timings table compares).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SortAlgo {
    /// Median-of-three quicksort with insertion-sort cutoff.
    Quick,
    /// Top-down mergesort (stable).
    Merge,
    /// Flashsort-style distribution sort with k = 0.42 m classes
    /// (Neubert 1998), falling back to insertion within classes.
    Flash,
    /// The standard library's pdqsort (unstable) as the reference.
    Std,
}

impl SortAlgo {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "quick" | "quicksort" => Some(SortAlgo::Quick),
            "merge" | "mergesort" => Some(SortAlgo::Merge),
            "flash" | "flashsort" => Some(SortAlgo::Flash),
            "std" => Some(SortAlgo::Std),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SortAlgo::Quick => "quick",
            SortAlgo::Merge => "merge",
            SortAlgo::Flash => "flash",
            SortAlgo::Std => "std",
        }
    }

    /// Sort `xs` descending by key.
    pub fn sort_desc<T: Keyed + Clone>(&self, xs: &mut [T]) {
        match self {
            SortAlgo::Quick => quicksort_desc(xs),
            SortAlgo::Merge => mergesort_desc(xs),
            SortAlgo::Flash => flashsort_desc(xs),
            SortAlgo::Std => {
                xs.sort_by(|a, b| b.key().partial_cmp(&a.key()).unwrap())
            }
        }
    }
}

const INSERTION_CUTOFF: usize = 16;

fn insertion_desc<T: Keyed + Clone>(xs: &mut [T]) {
    for i in 1..xs.len() {
        let mut j = i;
        while j > 0 && xs[j - 1].key() < xs[j].key() {
            xs.swap(j - 1, j);
            j -= 1;
        }
    }
}

/// Median-of-three quicksort, descending.
///
/// Iterative on the larger half (recursion only into the smaller half)
/// so stack depth is O(log m) even on adversarial inputs.
pub fn quicksort_desc<T: Keyed + Clone>(xs: &mut [T]) {
    let mut xs = xs;
    loop {
        if xs.len() <= INSERTION_CUTOFF {
            insertion_desc(xs);
            return;
        }
        let (lo, mid, hi) = (0, xs.len() / 2, xs.len() - 1);
        // median-of-three pivot selection: order the three, take the middle
        if xs[lo].key() < xs[mid].key() {
            xs.swap(lo, mid);
        }
        if xs[lo].key() < xs[hi].key() {
            xs.swap(lo, hi);
        }
        if xs[mid].key() < xs[hi].key() {
            xs.swap(mid, hi);
        }
        let pivot = xs[mid].key();
        // Hoare partition, descending: left >= pivot, right <= pivot.
        let mut i = 0usize;
        let mut j = xs.len() - 1;
        loop {
            while xs[i].key() > pivot {
                i += 1;
            }
            while xs[j].key() < pivot {
                j -= 1;
            }
            if i >= j {
                break;
            }
            xs.swap(i, j);
            i += 1;
            j -= 1;
        }
        let split = j + 1;
        let (left, right) = xs.split_at_mut(split);
        if left.len() < right.len() {
            quicksort_desc(left);
            xs = right;
        } else {
            quicksort_desc(right);
            xs = left;
        }
    }
}

/// Top-down stable mergesort, descending.
pub fn mergesort_desc<T: Keyed + Clone>(xs: &mut [T]) {
    let n = xs.len();
    if n <= INSERTION_CUTOFF {
        insertion_desc(xs);
        return;
    }
    let mid = n / 2;
    mergesort_desc(&mut xs[..mid]);
    mergesort_desc(&mut xs[mid..]);
    let mut merged = Vec::with_capacity(n);
    let (mut i, mut j) = (0, mid);
    while i < mid && j < n {
        if xs[i].key() >= xs[j].key() {
            merged.push(xs[i].clone());
            i += 1;
        } else {
            merged.push(xs[j].clone());
            j += 1;
        }
    }
    merged.extend_from_slice(&xs[i..mid]);
    merged.extend_from_slice(&xs[j..n]);
    xs.clone_from_slice(&merged);
}

/// Flashsort-style distribution sort, descending.
///
/// Classifies elements into k = max(1, 0.42 m) classes by linear
/// interpolation between min and max key, concatenates classes from
/// heaviest to lightest, then insertion-sorts within the result (classes
/// are nearly sorted).  O(m) average for near-uniform keys; worst case
/// O(m^2) like the paper notes (§4.1).
pub fn flashsort_desc<T: Keyed + Clone>(xs: &mut [T]) {
    let m = xs.len();
    if m <= INSERTION_CUTOFF {
        insertion_desc(xs);
        return;
    }
    let lo = xs.iter().map(|x| x.key()).fold(f64::INFINITY, f64::min);
    let hi = xs.iter().map(|x| x.key()).fold(f64::NEG_INFINITY, f64::max);
    if hi == lo {
        return; // all equal
    }
    let k = ((0.42 * m as f64) as usize).max(1);
    let scale = (k - 1) as f64 / (hi - lo);
    // class of x: heavier -> lower class index (descending output)
    let class = |x: &T| -> usize { (k - 1) - ((x.key() - lo) * scale) as usize };
    let mut counts = vec![0usize; k + 1];
    for x in xs.iter() {
        counts[class(x) + 1] += 1;
    }
    for c in 1..=k {
        counts[c] += counts[c - 1];
    }
    let mut out: Vec<Option<T>> = vec![None; m];
    let mut cursor = counts.clone();
    for x in xs.iter() {
        let c = class(x);
        out[cursor[c]] = Some(x.clone());
        cursor[c] += 1;
    }
    for (slot, val) in xs.iter_mut().zip(out.into_iter()) {
        *slot = val.unwrap();
    }
    // classes are internally unsorted: finish with insertion sort (cheap,
    // each class is short for near-uniform keys)
    insertion_desc(xs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn is_desc(xs: &[f64]) -> bool {
        xs.windows(2).all(|w| w[0] >= w[1])
    }

    fn check_algo(algo: SortAlgo, seed: u64, n: usize) {
        let mut rng = Pcg64::new(seed);
        let mut xs: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 100.0)).collect();
        let mut want = xs.clone();
        want.sort_by(|a, b| b.partial_cmp(a).unwrap());
        algo.sort_desc(&mut xs);
        assert!(is_desc(&xs), "{algo:?} not descending");
        assert_eq!(xs, want, "{algo:?} wrong permutation");
    }

    #[test]
    fn all_algos_random_inputs() {
        for algo in [SortAlgo::Quick, SortAlgo::Merge, SortAlgo::Flash, SortAlgo::Std] {
            for (seed, n) in [(1, 0), (2, 1), (3, 2), (4, 17), (5, 100), (6, 1000)] {
                check_algo(algo, seed, n);
            }
        }
    }

    #[test]
    fn sorted_and_reversed_inputs() {
        for algo in [SortAlgo::Quick, SortAlgo::Merge, SortAlgo::Flash] {
            let mut asc: Vec<f64> = (0..200).map(|i| i as f64).collect();
            algo.sort_desc(&mut asc);
            assert!(is_desc(&asc));
            let mut desc: Vec<f64> = (0..200).rev().map(|i| i as f64).collect();
            algo.sort_desc(&mut desc);
            assert!(is_desc(&desc));
        }
    }

    #[test]
    fn all_equal_input() {
        for algo in [SortAlgo::Quick, SortAlgo::Merge, SortAlgo::Flash] {
            let mut xs = vec![3.25f64; 500];
            algo.sort_desc(&mut xs);
            assert!(xs.iter().all(|&x| x == 3.25));
        }
    }

    #[test]
    fn many_duplicates() {
        let mut rng = Pcg64::new(9);
        for algo in [SortAlgo::Quick, SortAlgo::Merge, SortAlgo::Flash] {
            let mut xs: Vec<f64> = (0..500).map(|_| rng.below(5) as f64).collect();
            let mut want = xs.clone();
            want.sort_by(|a, b| b.partial_cmp(a).unwrap());
            algo.sort_desc(&mut xs);
            assert_eq!(xs, want, "{algo:?}");
        }
    }

    #[test]
    fn sorts_loads_by_weight() {
        use crate::load::Load;
        let mut loads = vec![
            Load::new(0, 1.0),
            Load::new(1, 5.0),
            Load::new(2, 3.0),
        ];
        SortAlgo::Quick.sort_desc(&mut loads);
        let ids: Vec<u64> = loads.iter().map(|l| l.id).collect();
        assert_eq!(ids, vec![1, 2, 0]);
    }

    #[test]
    fn mergesort_stable_on_ties() {
        use crate::load::Load;
        let mut loads: Vec<Load> = (0..50).map(|i| Load::new(i, (i % 3) as f64)).collect();
        SortAlgo::Merge.sort_desc(&mut loads);
        // stability: equal keys keep id order
        for w in loads.windows(2) {
            if w[0].weight == w[1].weight {
                assert!(w[0].id < w[1].id);
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["quick", "merge", "flash", "std"] {
            let a = SortAlgo::parse(s).unwrap();
            assert_eq!(SortAlgo::parse(a.name()), Some(a));
        }
        assert_eq!(SortAlgo::parse("bogo"), None);
    }
}
