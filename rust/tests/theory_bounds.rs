//! E8: measured protocol behaviour stays inside the §3 theory envelope.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{run, Schedule, StopRule};
use bcm_dlb::experiments::validate::validate;
use bcm_dlb::graph::{round_matrix, spectral, Graph, Topology};
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::theory;
use bcm_dlb::util::rng::Pcg64;

#[test]
fn theorem1_envelope_holds_across_topologies() {
    for topo in [
        Topology::Ring,
        Topology::Torus2d,
        Topology::Hypercube,
        Topology::RandomConnected,
    ] {
        for n in [8usize, 16, 64] {
            let r = validate(&topo, n, 50, 77);
            assert!(
                r.within_bound,
                "{topo:?} n={n}: final {} > bound {}",
                r.measured_final_disc, r.discrete_bound
            );
        }
    }
}

#[test]
fn contraction_factor_orders_topologies() {
    // Denser graphs contract faster than rings.  The hypercube's
    // dimension-exchange schedule is special: the product of its d
    // matchings is EXACTLY the uniform averaging matrix, so one sweep
    // balances perfectly (sigma2 = 0) — the classical dimension-exchange
    // result.
    let n = 16;
    let mut rng = Pcg64::new(5);
    let sig = |topo: Topology, rng: &mut Pcg64| {
        let g = topo.build(n, rng);
        let s = Schedule::from_graph(&g);
        let m = round_matrix(n, s.matchings());
        spectral::contraction_factor(&m, 500, 3)
    };
    let ring = sig(Topology::Ring, &mut rng);
    let hyper = sig(Topology::Hypercube, &mut rng);
    let complete = sig(Topology::Complete, &mut rng);
    assert!(hyper < 1e-6, "hypercube sweep should average exactly, got {hyper}");
    assert!(complete < ring, "complete {complete} >= ring {ring}");
    assert!(ring > 0.5 && ring < 1.0, "ring contraction {ring}");
}

#[test]
fn convergence_rate_tracks_spectral_gap() {
    // A graph with a larger spectral gap reaches a fixed target in fewer
    // rounds (comparing ring vs complete at the same n and load set).
    let n = 16;
    let mut rounds_for = |topo: Topology| -> usize {
        let mut rng = Pcg64::new(9);
        let g = topo.build(n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::init_uniform_counts(
            n,
            50,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let target = state.discrepancy() / 20.0;
        let trace = run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(300),
            &mut rng,
        );
        trace.rounds_to_reach(target).unwrap_or(usize::MAX)
    };
    let ring_rounds = rounds_for(Topology::Ring);
    let complete_rounds = rounds_for(Topology::Complete);
    assert!(
        complete_rounds < ring_rounds,
        "complete {complete_rounds} >= ring {ring_rounds}"
    );
}

#[test]
fn lemma5_error_bound_empirical() {
    // per-matching error |e_f - e_c| <= l1/2 (Lemma 5): verify over many
    // random two-bin instances.
    use bcm_dlb::balancer::sorted_greedy;
    for seed in 0..100 {
        let mut rng = Pcg64::new(seed);
        let m = 1 + rng.below(60);
        let weights: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 100.0)).collect();
        let l1 = weights.iter().cloned().fold(0.0, f64::max);
        let p = sorted_greedy(&weights, 2, SortAlgo::Quick);
        // e_f = |U0 - U1| / 2 distance from the perfect half-split
        let total: f64 = weights.iter().sum();
        let e_f = (p.sums[0] - total / 2.0).abs();
        assert!(
            e_f <= theory::lemma5_max_error(l1) + 1e-9,
            "seed {seed}: e_f {e_f} > l1/2 {}",
            l1 / 2.0
        );
    }
}

#[test]
fn tau_cont_predicts_continuous_convergence() {
    // The continuous process x <- xM reaches eps-discrepancy within
    // tau_cont rounds (the bound must hold for the linear system itself).
    let n = 12;
    let mut rng = Pcg64::new(11);
    let g = Graph::random_connected(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let m = round_matrix(n, schedule.matchings());
    let lambda = spectral::contraction_factor(&m, 500, 1);
    let mut x: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 100.0)).collect();
    let k = {
        let max = x.iter().cloned().fold(f64::MIN, f64::max);
        let min = x.iter().cloned().fold(f64::MAX, f64::min);
        max - min
    };
    let eps = 0.5;
    let tau_sweeps =
        theory::tau_cont(k, eps, n, schedule.period(), lambda) / schedule.period() as f64;
    let mut sweeps = 0usize;
    loop {
        x = m.apply_left(&x);
        sweeps += 1;
        let max = x.iter().cloned().fold(f64::MIN, f64::max);
        let min = x.iter().cloned().fold(f64::MAX, f64::min);
        if max - min <= eps {
            break;
        }
        assert!(
            (sweeps as f64) <= tau_sweeps.max(1.0) + 1.0,
            "continuous process exceeded tau bound: {sweeps} > {tau_sweeps}"
        );
    }
}

#[test]
fn sustained_plateau_stays_under_the_berenbrink_bound() {
    // The dynamic regime (Berenbrink et al., arXiv 2302.12201): under
    // the default service-traffic churn, each BCM protocol's *measured*
    // sustained discrepancy must sit below the predicted plateau
    // churn_per_sweep / (1 - lambda) + discrete floor — the E14
    // predicted_bound column.
    use bcm_dlb::experiments::run_dynamic_experiment;
    use bcm_dlb::workload::TrafficConfig;
    let r = run_dynamic_experiment(
        &Topology::RandomConnected,
        16,
        20,
        48,
        16,
        2013,
        &TrafficConfig::default(),
    );
    for c in &r.cells {
        let bound = c.predicted_bound.expect("n=16 is under the spectral cap");
        assert!(bound.is_finite() && bound > 0.0, "{}: bad bound {bound}", c.name);
        if c.name.starts_with("bcm/") {
            assert!(
                c.sustained.max <= bound,
                "{}: sustained max {} exceeds predicted plateau {bound}",
                c.name,
                c.sustained.max
            );
        }
    }
    // the bound is a *plateau* prediction, not a vacuous infinity: it
    // must sit within a few orders of magnitude of the measurement
    let sorted = &r.cells[0];
    let bound = sorted.predicted_bound.unwrap();
    assert!(
        bound < sorted.sustained.max * 1e6,
        "bound {bound} is vacuously loose vs measured {}",
        sorted.sustained.max
    );
}

#[test]
fn discrete_floor_scales_with_lmax() {
    // Indivisibility floor: scaling all weights by c scales the final
    // discrepancy by ~c (the protocol is scale-equivariant).
    let run_with_scale = |scale: f64| -> f64 {
        let mut rng = Pcg64::new(13);
        let g = Graph::random_connected(16, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::init_uniform_counts(
            16,
            50,
            &WeightDistribution::Uniform { lo: 0.0, hi: scale },
            Mobility::Full,
            &mut rng,
        );
        let trace = run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(25),
            &mut rng,
        );
        trace.final_discrepancy()
    };
    let d1 = run_with_scale(1.0);
    let d100 = run_with_scale(100.0);
    // identical seeds -> identical protocol decisions -> exact scaling
    assert!((d100 / d1 - 100.0).abs() < 1.0, "d1={d1} d100={d100}");
}
