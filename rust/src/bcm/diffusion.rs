//! Diffusion-based DLB — the *other* subclass of scalable local schemes
//! the paper positions BCM against (§1: Cybenko 1989, Boillat 1990).
//!
//! First-order scheme (FOS): every round, every node exchanges with ALL
//! neighbors simultaneously; the continuous update is
//! `x_u += sum_v alpha * (x_v − x_u)` with `alpha <= 1/(maxdeg+1)` for
//! stability.  With indivisible real-valued loads the prescribed flow on
//! each edge is realized greedily: the heavier endpoint sends its loads
//! (largest-first that still fits) until the transferred weight reaches
//! the continuous flow target.
//!
//! This gives the benches a genuine cross-family baseline: diffusion
//! needs one-to-all communication per round and its indivisible rounding
//! error accumulates per edge, whereas the BCM pairs balance exactly.

use super::trace::{RoundStats, RunTrace};
use crate::graph::Graph;
use crate::load::{Load, LoadState};
use crate::util::rng::Pcg64;

/// First-order-diffusion protocol with greedy indivisible rounding.
#[derive(Default)]
pub struct Diffusion {
    /// Edge weight alpha; None = 1/(maxdeg+1) (the safe uniform choice).
    pub alpha: Option<f64>,
}

impl Diffusion {
    /// Run `rounds` diffusion rounds, mutating `state`.
    pub fn run(
        &self,
        state: &mut LoadState,
        g: &Graph,
        rounds: usize,
        rng: &mut Pcg64,
    ) -> RunTrace {
        assert_eq!(state.n(), g.n());
        let alpha = self
            .alpha
            .unwrap_or_else(|| 1.0 / (g.max_degree() as f64 + 1.0));
        let mut trace = RunTrace {
            initial_discrepancy: state.discrepancy(),
            rounds: Vec::new(),
        };
        for round in 0..rounds {
            let x = state.load_vector();
            let mut movements = 0usize;
            // Continuous flow target per edge, then greedy rounding.
            for &(u, v) in g.edges() {
                let (u, v) = (u as usize, v as usize);
                let flow = alpha * (x[u] - x[v]); // >0: u -> v
                let (from, to, want) = if flow >= 0.0 {
                    (u, v, flow)
                } else {
                    (v, u, -flow)
                };
                movements += transfer_greedy(state, from, to, want, rng);
            }
            trace.rounds.push(RoundStats {
                round,
                color: 0,
                discrepancy: state.discrepancy(),
                movements,
                edges: g.num_edges(),
            });
        }
        trace
    }
}

/// Move mobile loads from `from` to `to`, largest-first among those that
/// fit, until the moved weight reaches `want`.  Returns loads moved.
fn transfer_greedy(
    state: &mut LoadState,
    from: usize,
    to: usize,
    want: f64,
    _rng: &mut Pcg64,
) -> usize {
    if want <= 0.0 {
        return 0;
    }
    let mut mobile = state.take_mobile(from);
    // largest first that still fits within the remaining budget: sort
    // descending once, then single pass.
    mobile.sort_by(|a, b| b.weight.partial_cmp(&a.weight).unwrap());
    let mut remaining = want;
    let mut kept: Vec<Load> = Vec::with_capacity(mobile.len());
    let mut moved = 0usize;
    for l in mobile {
        // send only if it does not overshoot the target by more than it
        // helps: greedy rounding = send while weight <= remaining budget
        // (plus one final partial-fit heuristic: send if it halves the
        // residual)
        if l.weight <= remaining {
            remaining -= l.weight;
            state.push(to, l);
            moved += 1;
        } else {
            kept.push(l);
        }
    }
    state.give(from, kept);
    moved
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::load::{Mobility, WeightDistribution};

    #[test]
    fn diffusion_reduces_discrepancy() {
        let mut rng = Pcg64::new(1);
        let g = Graph::random_connected(16, &mut rng);
        let mut state = LoadState::init_uniform_counts(
            16,
            50,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let init = state.discrepancy();
        let trace = Diffusion::default().run(&mut state, &g, 250, &mut rng);
        // FOS with greedy indivisible rounding stalls at a floor once
        // every per-edge flow target drops below the movable load
        // weights — exactly the limitation that motivates the paper's
        // matching model (bcm_beats_diffusion_on_final_discrepancy shows
        // the gap).  Expect improvement, not convergence.
        assert!(
            trace.final_discrepancy() < init / 2.0,
            "init {init} final {}",
            trace.final_discrepancy()
        );
    }

    #[test]
    fn diffusion_conserves_loads_and_mass() {
        let mut rng = Pcg64::new(2);
        let g = Graph::torus2d(4, 4);
        let mut state = LoadState::init_uniform_counts(
            16,
            30,
            &WeightDistribution::paper_section6(),
            Mobility::Partial,
            &mut rng,
        );
        let ids = state.all_ids();
        let mass = state.total_weight();
        Diffusion::default().run(&mut state, &g, 20, &mut rng);
        assert_eq!(state.all_ids(), ids);
        assert!((state.total_weight() - mass).abs() < 1e-6);
    }

    #[test]
    fn transfer_respects_budget() {
        let mut rng = Pcg64::new(3);
        let mut state = LoadState::empty(2);
        for i in 0..10 {
            state.push(0, Load::new(i, 5.0));
        }
        let moved = transfer_greedy(&mut state, 0, 1, 12.0, &mut rng);
        assert_eq!(moved, 2); // two 5.0 loads fit within 12.0
        assert_eq!(state.node_weight(1), 10.0);
    }

    #[test]
    fn transfer_skips_pinned() {
        let mut rng = Pcg64::new(4);
        let mut state = LoadState::empty(2);
        state.push(0, Load::pinned(0, 50.0));
        state.push(0, Load::new(1, 5.0));
        let moved = transfer_greedy(&mut state, 0, 1, 100.0, &mut rng);
        assert_eq!(moved, 1);
        assert!(state.node(0).iter().any(|l| l.id == 0));
    }

    #[test]
    fn custom_alpha_stable() {
        let mut rng = Pcg64::new(5);
        let g = Graph::ring(8);
        let mut state = LoadState::init_uniform_counts(
            8,
            40,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let init = state.discrepancy();
        let d = Diffusion { alpha: Some(0.25) };
        let trace = d.run(&mut state, &g, 50, &mut rng);
        assert!(trace.final_discrepancy() <= init);
    }

    #[test]
    fn bcm_beats_diffusion_on_final_discrepancy() {
        // The paper's §2 premise: the matching model reaches better local
        // balance than diffusion for indivisible loads.
        use crate::balancer::{PairAlgorithm, SortAlgo};
        use crate::bcm::{run, Schedule, StopRule};
        let mut rng = Pcg64::new(6);
        let g = Graph::random_connected(16, &mut rng);
        let state0 = LoadState::init_uniform_counts(
            16,
            50,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let mut s1 = state0.clone();
        let mut r1 = Pcg64::new(10);
        let schedule = Schedule::from_graph(&g);
        let bcm = run(
            &mut s1,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(10),
            &mut r1,
        );
        let mut s2 = state0;
        let mut r2 = Pcg64::new(20);
        let dif = Diffusion::default().run(&mut s2, &g, 10 * schedule.period(), &mut r2);
        assert!(
            bcm.final_discrepancy() < dif.final_discrepancy(),
            "bcm {} vs diffusion {}",
            bcm.final_discrepancy(),
            dif.final_discrepancy()
        );
    }
}
